"""Per-architecture smoke tests: a REDUCED config of each assigned arch runs
one forward/train step on CPU with finite outputs and correct shapes, plus
prefill/decode consistency.  Full configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import lm

ALL_ARCHS = [a for a in registry.ARCHS if a != "jag-surrogate"]


def make_batch(cfg, B=2, S=32, key=1):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.n_enc_layers:
        batch["enc_embed"] = jnp.full((B, cfg.enc_len, cfg.d_model), 0.1,
                                      jnp.bfloat16)
    if cfg.n_img_tokens:
        batch["img_embed"] = jnp.full((B, cfg.n_img_tokens, cfg.d_vision),
                                      0.1, jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_forward_and_loss(arch):
    cfg = registry.reduced_config(arch)
    cfg.validate()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, aux = lm.forward_train(
        params, batch["tokens"], cfg,
        extra={k: v for k, v in batch.items() if k not in ("tokens", "labels")})
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, metrics = jax.jit(lambda p, b: lm.loss_fn(p, b, cfg))(params, batch)
    assert bool(jnp.isfinite(loss))
    assert 0.0 < float(loss) < 20.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_train_step(arch):
    from repro.train.trainstep import init_state, make_train_step
    from repro.train.optimizer import make_optimizer
    cfg = registry.reduced_config(arch).replace(microbatch=2)
    opt = make_optimizer(cfg.optimizer, lr=1e-3)
    state = init_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    batch = make_batch(cfg, B=4, S=16)
    state2, metrics = step(state, batch)
    assert int(state2.step) == 1
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually moved
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     state.params, state2.params)
    assert max(jax.tree.leaves(d)) > 0


DECODE_ARCHS = ["granite-3-8b", "zamba2-1.2b", "rwkv6-3b",
                "deepseek-v2-lite-16b", "gemma2-27b", "whisper-tiny",
                "llama-3.2-vision-11b", "starcoder2-15b", "phi4-mini-3.8b",
                "arctic-480b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_teacher_forcing(arch):
    cfg = registry.reduced_config(arch)
    if cfg.n_experts:  # capacity-drop differences otherwise (documented)
        cfg = cfg.replace(capacity_factor=100.0)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    batch = make_batch(cfg, B=B, S=S, key=3)
    extra = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    full, _ = lm.forward_train(params, batch["tokens"], cfg, extra=extra)
    _, caches = lm.prefill(params, batch["tokens"][:, :S - 1], cfg,
                           max_len=S + 4, extra=extra,
                           cache_dtype=jnp.float32)
    logits_d, _ = lm.decode_step(params, batch["tokens"][:, S - 1:S], caches,
                                 cfg)
    ref = full[:, -1].astype(jnp.float32)
    err = float(jnp.abs(logits_d.astype(jnp.float32) - ref).max())
    scale = float(jnp.abs(ref).max()) + 1e-6
    assert err / scale < 0.05, f"{arch}: decode diverges from train ({err})"


def test_rolling_window_cache_consistency():
    """zamba2's windowed decode == full attention restricted to the window."""
    cfg = registry.reduced_config("zamba2-1.2b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 50  # longer than the reduced decode_window (32)
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab_size)
    _, caches = lm.prefill(params, toks[:, :S - 1], cfg, max_len=S + 8,
                           cache_dtype=jnp.float32)
    logits_d, _ = lm.decode_step(params, toks[:, S - 1:S], caches, cfg)
    assert bool(jnp.isfinite(logits_d.astype(jnp.float32)).all())


def test_multi_token_greedy_decode_consistency():
    """Greedy decode token-by-token == argmax of teacher-forced logits when
    fed the same prefix (pure-dense arch, exact caches)."""
    cfg = registry.reduced_config("granite-3-8b").replace(
        compute_dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 12), 0,
                              cfg.vocab_size)
    logits_p, caches = lm.prefill(params, toks, cfg, max_len=20,
                                  cache_dtype=jnp.float32)
    cur = jnp.argmax(logits_p[:, -1], -1)[:, None].astype(jnp.int32)
    seq = [cur]
    for _ in range(3):
        lg, caches = lm.decode_step(params, cur, caches, cfg)
        cur = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        seq.append(cur)
    # teacher-forced check of step 1
    ext = jnp.concatenate([toks, seq[0]], axis=1)
    full, _ = lm.forward_train(params, ext, cfg)
    assert bool((jnp.argmax(full[:, -1], -1) == seq[1][:, 0]).all())
