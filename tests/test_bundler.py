"""Bundling/aggregation + crawl invariants (paper Fig. 7)."""
import os

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bundler import Bundler, missing_samples


def test_write_aggregate_load_roundtrip(tmp_path):
    b = Bundler(str(tmp_path), files_per_leaf=3)
    rng = np.random.default_rng(0)
    truth = rng.random((30, 4)).astype(np.float32)
    for lo in range(0, 30, 5):
        b.write_bundle(lo, lo + 5, {"x": truth[lo:lo + 5]})
    present, corrupt = b.crawl()
    assert present == set(range(30)) and not corrupt
    b.aggregate_all()
    # bundles subsumed: only aggregates remain
    files = [f for _, _, fs in os.walk(str(tmp_path)) for f in fs]
    assert all(f == "aggregate.npz" for f in files)
    data = b.load_all()
    assert np.allclose(data["x"], truth)


def test_crawl_detects_corruption(tmp_path):
    b = Bundler(str(tmp_path))
    b.write_bundle(0, 5, {"x": np.ones(5)})
    b.write_bundle(5, 10, {"x": np.ones(5)})
    # corrupt one file in place
    victim = None
    for root, _, files in os.walk(str(tmp_path)):
        for f in files:
            if f.startswith("bundle_000000005"):
                victim = os.path.join(root, f)
    assert victim is not None
    with open(victim, "wb") as f:
        f.write(b"garbage")
    present, corrupt = b.crawl()
    assert present == set(range(5))
    assert len(corrupt) == 1


@given(st.sets(st.integers(0, 199)))
@settings(max_examples=50, deadline=None)
def test_missing_samples_ranges(present):
    ranges = missing_samples(200, present)
    rebuilt = set()
    for lo, hi in ranges:
        assert lo < hi
        rebuilt.update(range(lo, hi))
    assert rebuilt == set(range(200)) - present
    # ranges are maximal (no two adjacent)
    for (a, b), (c, d) in zip(ranges, ranges[1:]):
        assert c > b


def test_concurrent_writers_no_interference(tmp_path):
    import threading
    b = Bundler(str(tmp_path), files_per_leaf=10)

    def write(lo):
        Bundler(str(tmp_path), files_per_leaf=10).write_bundle(
            lo, lo + 2, {"x": np.full(2, lo)})

    ts = [threading.Thread(target=write, args=(lo,)) for lo in range(0, 40, 2)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    present, corrupt = b.crawl()
    assert present == set(range(40)) and not corrupt
