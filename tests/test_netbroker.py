"""NetBroker/BrokerServer: protocol conformance, reconnect semantics,
server-held leases, and the two-process (no shared queue filesystem)
deployment.  All socket tests carry the ``net`` marker so restricted
sandboxes can deselect them with ``-m 'not net'``."""
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import Bundler, MerlinRuntime, Step, StudySpec, WorkerPool
from repro.core.hierarchy import HierarchyCfg
from repro.core.netbroker import (AuthError, BrokerServer, NetBroker,
                                  hello_mac, make_broker, parse_address)
from repro.core.queue import (Broker, BrokerError, BrokerUnavailable,
                              FileBroker, InMemoryBroker, new_task)
from repro.core.resilience import SpeculativeReissuer


# ---------------------------------------------------------------------------
# protocol / factory (no sockets)
# ---------------------------------------------------------------------------

def test_local_backends_satisfy_broker_protocol(tmp_path):
    assert isinstance(InMemoryBroker(), Broker)
    assert isinstance(FileBroker(str(tmp_path / "q")), Broker)


def test_parse_address():
    assert parse_address("tcp://10.0.0.5:6672") == ("10.0.0.5", 6672)
    assert parse_address("localhost:80") == ("localhost", 80)
    with pytest.raises(ValueError):
        parse_address("tcp://nohost")


def test_make_broker_urls(tmp_path):
    assert isinstance(make_broker("mem://"), InMemoryBroker)
    fb = make_broker(f"file://{tmp_path}/q", visibility_timeout=1.0)
    assert isinstance(fb, FileBroker)
    assert fb.root == f"{tmp_path}/q"
    nb = make_broker("tcp://127.0.0.1:6672")
    assert isinstance(nb, NetBroker)
    with pytest.raises(ValueError):
        make_broker("amqp://guest@rabbit")


# ---------------------------------------------------------------------------
# wire behavior
# ---------------------------------------------------------------------------

@pytest.fixture
def served_mem():
    server = BrokerServer(InMemoryBroker(visibility_timeout=0.5)).start()
    client = NetBroker(server.address, reconnect_timeout=2.0)
    yield server, client
    client.close()
    server.stop()


@pytest.mark.net
def test_netbroker_satisfies_broker_protocol(served_mem):
    # needs a live server: the protocol check probes the stats property
    server, nb = served_mem
    assert isinstance(nb, Broker)


@pytest.mark.net
def test_reack_is_idempotent_over_the_wire(served_mem):
    """A client that re-sends an ack after losing the response must no-op."""
    server, nb = served_mem
    nb.put(new_task("real", {}))
    lease = nb.get(timeout=1)
    nb.ack(lease.tag)
    nb.ack(lease.tag)  # retry after a hypothetical lost response
    assert nb.stats["acked"] == 1
    assert nb.idle()


@pytest.mark.net
def test_vanished_client_lease_expires_server_side(served_mem):
    """Server-held leases: a client that dies mid-lease never acks; the
    task redelivers to the next consumer like any dead worker's."""
    server, nb = served_mem
    nb.put(new_task("real", {"x": 1}))
    doomed = NetBroker(server.address)
    assert doomed.get(timeout=1) is not None
    doomed.close()  # the client vanishes without acking
    lease = nb.get(timeout=5)  # vt=0.5: expiry redelivers
    assert lease is not None and lease.task.retries == 1
    nb.ack(lease.tag)
    assert nb.idle()


@pytest.mark.net
def test_unknown_op_and_closed_client_raise(served_mem):
    server, nb = served_mem
    with pytest.raises(BrokerError):
        nb._call("frobnicate")
    nb.close()
    with pytest.raises(BrokerError):
        nb.qsize()


@pytest.mark.net
def test_unreachable_server_raises_broker_unavailable():
    nb = NetBroker("tcp://127.0.0.1:1", reconnect_timeout=0.3,
                   connect_timeout=0.2)
    with pytest.raises(BrokerUnavailable):
        nb.qsize()


@pytest.mark.net
def test_garbage_connection_does_not_kill_server(served_mem):
    """A client speaking garbage (say, HTTP) is dropped; the broker keeps
    serving everyone else."""
    import socket as socketlib
    server, nb = served_mem
    raw = socketlib.create_connection(("127.0.0.1", server.port))
    raw.sendall(b"GET / HTTP/1.1\r\nHost: x\r\n\r\n" * 100)
    raw.close()
    nb.put(new_task("real", {"ok": 1}))
    lease = nb.get(timeout=2)
    assert lease.task.payload == {"ok": 1}
    nb.ack(lease.tag)


@pytest.mark.net
def test_per_queue_visibility_timeout_over_the_wire(served_mem):
    """set_visibility_timeout relays to the backend: the 'fast' queue's
    lease expires and redelivers while the default queue's lease (vt=0.5 at
    lease time... still longer) stays leased."""
    server, nb = served_mem
    nb.set_visibility_timeout("fast", 0.1)
    nb.set_visibility_timeout("slow", 30.0)
    nb.put(new_task("real", {"q": "fast"}, queue="fast"))
    nb.put(new_task("real", {"q": "slow"}, queue="slow"))
    l_fast = nb.get(timeout=1, queues=("fast",))
    l_slow = nb.get(timeout=1, queues=("slow",))
    assert l_fast and l_slow
    redelivered = nb.get(timeout=2)  # only the fast lease may come back
    assert redelivered is not None
    assert redelivered.task.queue == "fast"
    assert redelivered.task.retries == 1
    nb.ack(redelivered.tag)  # or IT would expire again (vt=0.1)
    assert nb.get(timeout=0.1) is None  # slow stays leased (vt=30)


@pytest.mark.net
def test_speculative_reissuer_against_remote_broker(served_mem):
    """Straggler reissue works through the protocol's inflight_tasks()."""
    server, nb = served_mem
    nb.put(new_task("real", {"x": 1}, queue="sims"))
    stuck = nb.get(timeout=1)
    assert stuck is not None
    reissuer = SpeculativeReissuer(nb, dup_after=0.05)
    time.sleep(0.1)
    assert reissuer.scan_once() == 1
    assert reissuer.scan_once() == 0  # max_dups honored
    dup = nb.get(timeout=1)
    assert dup.task.payload == {"x": 1} and dup.task.queue == "sims"
    nb.ack(dup.tag)
    nb.ack(stuck.tag)


@pytest.mark.net
def test_dead_letter_over_the_wire(tmp_path):
    """A poison task file in the server's FileBroker backend is quarantined
    server-side; remote consumers just see a clean queue."""
    root = str(tmp_path / "q")
    backend = FileBroker(root, visibility_timeout=0.2)
    server = BrokerServer(backend).start()
    nb = NetBroker(server.address)
    try:
        nb.put(new_task("real", {"ok": 1}))
        poison = os.path.join(backend._qdir("default"),
                              "000-000000000000-x.json")
        with open(poison, "w") as f:
            f.write("{not json")
        lease = nb.get(timeout=1)
        assert lease.task.payload == {"ok": 1}
        nb.ack(lease.tag)
        assert nb.get(timeout=0.5) is None  # poison never delivered
        assert nb.idle()
        dead = os.listdir(os.path.join(root, "dead"))
        assert len(dead) == 1 and dead[0].endswith("x.json")
    finally:
        nb.close()
        server.stop()


@pytest.mark.net
def test_weighted_fairness_served_backend():
    """A flooding queue behind a weighted server cannot starve a trickle
    queue; starvation_avoided surfaces in remote stats."""
    server = BrokerServer(InMemoryBroker(fairness="weighted")).start()
    nb = NetBroker(server.address)
    try:
        nb.put_many([new_task("real", {"i": i}, queue="flood")
                     for i in range(50)])
        nb.put_many([new_task("real", {"i": i}, queue="trickle")
                     for i in range(3)])
        first_six = [nb.get(timeout=1).task.queue for _ in range(6)]
        # round-robin: the trickle queue appears within the first few
        # deliveries instead of waiting behind 50 flood tasks
        assert "trickle" in first_six[:2]
        assert nb.stats["starvation_avoided"] >= 1
    finally:
        nb.close()
        server.stop()


# ---------------------------------------------------------------------------
# the two-process deployment (broker-serve entrypoint)
# ---------------------------------------------------------------------------

@pytest.mark.net
@pytest.mark.slow
def test_two_process_study_via_broker_serve(tmp_path):
    """BrokerServer in its own OS process (the broker-serve entrypoint),
    MerlinRuntime + WorkerPool in this one.  The queue exists only in the
    server process — nothing under the study workspace holds queue state —
    and the study completes end to end."""
    port_file = str(tmp_path / "broker.port")
    env = {**os.environ,
           "PYTHONPATH": "src" + os.pathsep + os.environ.get("PYTHONPATH", "")}
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "broker-serve",
         "--port", "0", "--port-file", port_file],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env)
    try:
        deadline = time.monotonic() + 30
        while not os.path.exists(port_file):
            assert proc.poll() is None, "broker server died during startup"
            assert time.monotonic() < deadline, "server did not come up"
            time.sleep(0.05)
        with open(port_file) as f:
            url = f"tcp://127.0.0.1:{int(f.read())}"

        results = Bundler(str(tmp_path / "res"))
        rt = MerlinRuntime(broker=url, workspace=str(tmp_path / "ws"),
                           hierarchy=HierarchyCfg(max_fanout=4, bundle=8))
        rt.register("sim", lambda ctx: results.write_bundle(
            ctx.lo, ctx.hi, {"y": ctx.sample_block[:, 0]}))
        spec = StudySpec(name="twoproc", steps=[Step(name="sim", fn="sim")])
        with WorkerPool(rt, n_workers=3, batch=2) as pool:
            sid = rt.run(spec, np.arange(64, dtype=np.float32).reshape(64, 1))
            assert rt.wait(sid, timeout=90)
            assert pool.drain(timeout=30)
        assert np.allclose(np.sort(results.load_all()["y"]), np.arange(64))
        # no queue state on this side's filesystem
        ws_files = set()
        for dirpath, _, files in os.walk(str(tmp_path / "ws")):
            ws_files.update(files)
        assert not any(f.endswith(".json") and "-" in f and f[0:3].isdigit()
                       for f in ws_files), "queue files leaked into workspace"
        rt.broker.close()
    finally:
        proc.terminate()
        proc.wait(timeout=10)


# ---------------------------------------------------------------------------
# per-queue depth + status over the wire
# ---------------------------------------------------------------------------

@pytest.mark.net
def test_per_queue_depth_override_over_the_wire(served_mem):
    """set_max_queue_depth relays to the backend, and the resulting
    BrokerFull comes back as the TYPED error for every client."""
    from repro.core.queue import BrokerFull
    server, nb = served_mem
    server.backend._put_timeout = 0.2
    nb.set_max_queue_depth("gen", 1)
    nb.put(new_task("gen", {}, queue="gen"))
    with pytest.raises(BrokerFull):
        nb.put(new_task("gen", {}, queue="gen"))
    nb.set_max_queue_depth("gen", None)  # clearing relays too
    nb.put(new_task("gen", {}, queue="gen"))
    assert nb.qsize(("gen",)) == 2


@pytest.mark.net
def test_merlin_status_snapshot_over_the_wire(served_mem):
    """The merlin-status CLI's snapshot: depth / inflight / consumers per
    queue against a remote broker."""
    from repro.launch.serve import status_snapshot
    server, nb = served_mem
    nb.put_many([new_task("real", {}, queue="sims") for _ in range(3)])
    nb.put(new_task("gen", {}, queue="gen"))
    lease = nb.get(timeout=1, queues=("sims",))
    nb.heartbeat("w0", ("sims",))
    nb.heartbeat("w1", None)  # wildcard consumer
    snap = status_snapshot(nb)
    assert snap["queues"]["sims"] == {"depth": 2, "inflight": 1,
                                      "consumers": 1}
    assert snap["queues"]["gen"]["depth"] == 1
    assert snap["wildcard_consumers"] == 1
    assert snap["totals"] == {"depth": 3, "inflight": 1}
    assert snap["counters"]["enqueued"] == 4
    nb.ack(lease.tag)


@pytest.mark.net
def test_merlin_status_cli_renders_table(served_mem, capsys):
    from repro.launch.serve import merlin_status_main
    server, nb = served_mem
    nb.put(new_task("real", {}, queue="sims"))
    merlin_status_main(["--broker", server.address])
    out = capsys.readouterr().out
    assert "sims" in out and "depth" in out and "TOTAL" in out
    merlin_status_main(["--broker", server.address, "--json"])
    doc = json.loads(capsys.readouterr().out)
    assert doc["queues"]["sims"]["depth"] == 1


# ---------------------------------------------------------------------------
# shared-secret hello auth (REPRO_AUTH_TOKEN HMAC)
# ---------------------------------------------------------------------------

def test_hello_mac_binds_token_and_codec_offer():
    """The MAC covers the codec offer, so a captured hello cannot be
    replayed with a different negotiation."""
    mac = hello_mac("tok", ["bin1"])
    assert mac == hello_mac("tok", ["bin1"])  # deterministic
    assert mac != hello_mac("tok", ["json"])
    assert mac != hello_mac("other", ["bin1"])


@pytest.mark.net
def test_authed_hello_end_to_end():
    server = BrokerServer(InMemoryBroker(), auth_token="sekrit").start()
    nb = NetBroker(server.address, auth_token="sekrit")
    try:
        nb.put(new_task("real", {"x": 1}))
        lease = nb.get(timeout=2)
        assert lease is not None and lease.task.payload == {"x": 1}
        nb.ack(lease.tag)
        assert nb.idle()
        assert server.stats["auth_failures"] == 0
    finally:
        nb.close()
        server.stop()


@pytest.mark.net
def test_missing_or_wrong_token_is_refused_typed():
    """Unauthenticated ops come back as a typed AuthError (connection
    kept — the client may retry with the right MAC), never as silent
    drops or transport failures; the server keeps serving valid
    clients."""
    server = BrokerServer(InMemoryBroker(), auth_token="sekrit").start()
    anon = NetBroker(server.address, reconnect_timeout=2.0)
    wrong = NetBroker(server.address, auth_token="nope",
                      reconnect_timeout=2.0)
    good = NetBroker(server.address, auth_token="sekrit")
    try:
        with pytest.raises(AuthError):
            anon.qsize()
        with pytest.raises(AuthError):
            wrong.put(new_task("real", {}))
        assert server.stats["auth_failures"] >= 2
        # the refusals didn't poison the endpoint for valid clients
        good.put(new_task("real", {"ok": 1}))
        lease = good.get(timeout=2)
        assert lease.task.payload == {"ok": 1}
        good.ack(lease.tag)
    finally:
        anon.close()
        wrong.close()
        good.close()
        server.stop()


@pytest.mark.net
def test_auth_token_defaults_from_environment(monkeypatch):
    """NetBroker picks up REPRO_AUTH_TOKEN from the environment — the
    deployment path where workers inherit the secret, not a kwarg."""
    monkeypatch.setenv("REPRO_AUTH_TOKEN", "sekrit")
    server = BrokerServer(InMemoryBroker(), auth_token="sekrit").start()
    nb = NetBroker(server.address)
    try:
        nb.put(new_task("real", {}))
        assert nb.qsize() == 1
    finally:
        nb.close()
        server.stop()
