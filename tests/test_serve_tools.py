"""Ops CLI helpers: merlin-status --watch throughput derivation and the
merlin-validate spec gate."""
import json
import os

from repro.core.queue import InMemoryBroker, new_task
from repro.launch.serve import (main, merlin_validate_main, status_snapshot,
                                watch_rates)

SPEC_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "examples", "specs")


def test_watch_rates_from_acked_deltas():
    b = InMemoryBroker()
    b.put_many([new_task("real", {}, queue="sims") for _ in range(3)])
    b.put(new_task("real", {}, queue="post"))
    s0 = status_snapshot(b)
    assert watch_rates(None, 0.0, s0, 1.0) is None  # first poll: no history
    for _ in range(2):
        b.ack(b.get(timeout=1, queues=("sims",)).tag)
    b.ack(b.get(timeout=1, queues=("post",)).tag)
    s1 = status_snapshot(b)
    r = watch_rates(s0, 10.0, s1, 12.0)
    assert r["interval_s"] == 2.0
    assert r["tasks_per_s"] == {"post": 0.5, "sims": 1.0}
    assert r["total_tasks_per_s"] == 1.5


def test_watch_rates_clamp_counter_reset():
    # a broker restart zeroes its counters; the delta must clamp, not go
    # negative
    prev = {"acked_by_queue": {"sims": 50}}
    cur = {"acked_by_queue": {"sims": 3}}
    r = watch_rates(prev, 0.0, cur, 1.0)
    assert r["tasks_per_s"]["sims"] == 0.0


def test_validate_example_specs_all_pass(capsys):
    specs = sorted(os.path.join(SPEC_DIR, n) for n in os.listdir(SPEC_DIR)
                   if n.endswith(".yaml"))
    assert specs, "no example specs found"
    rc = merlin_validate_main(specs)
    assert rc == 0
    out = capsys.readouterr().out
    assert out.count("OK") == len(specs) and "FAIL" not in out


def test_validate_reports_structural_errors(tmp_path, capsys):
    bad = tmp_path / "bad.yaml"
    bad.write_text("description:\n  name: bad\nstudy:\n"
                   "  - name: a\n    run:\n      cmd: echo\n"
                   "      depends: [a]\n")
    rc = merlin_validate_main([str(bad)], )
    assert rc == 1
    assert "FAIL" in capsys.readouterr().out
    rc = merlin_validate_main([str(bad), "--json"])
    assert rc == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["ok"] is False and doc["spec"] == str(bad)


def test_main_dispatches_merlin_validate(capsys):
    rc = main(["merlin-validate",
               os.path.join(SPEC_DIR, "diamond.yaml"), "--json"])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["ok"] is True and doc["name"] == "diamond-demo"
    assert doc["nodes"] == ["prep", "left", "right", "join"]


def test_merlin_dlq_list_show_requeue(tmp_path, capsys):
    """The merlin-dlq CLI drains dead-letter queues over a broker URL:
    list depths, show parked tasks (and put them back), requeue them to
    their original queue with a fresh retry budget."""
    from repro.core.queue import FileBroker, Task
    url = f"file://{tmp_path}"
    seed = FileBroker(str(tmp_path))
    seed.put(Task(id="t-live", kind="real", payload={}, queue="sims"))
    for i in range(2):
        seed.put(Task(id=f"t-dead{i}", kind="real",
                      payload={"study": "s1"}, queue="dlq.sims",
                      retries=3))

    assert main(["merlin-dlq", "--broker", url, "list"]) == 0
    out = capsys.readouterr().out
    assert "dlq.sims" in out and "2" in out and "-> sims" in out

    assert main(["merlin-dlq", "--broker", url, "list", "--json"]) == 0
    rows = [json.loads(l) for l in capsys.readouterr().out.splitlines()]
    assert rows == [{"queue": "dlq.sims", "original": "sims", "depth": 2}]

    # show leases + nacks back: tasks stay parked
    assert main(["merlin-dlq", "--broker", url, "show"]) == 0
    out = capsys.readouterr().out
    assert out.count("parked t-dead") == 2 and "2 task(s) shown" in out
    assert FileBroker(str(tmp_path)).qsize(("dlq.sims",)) == 2

    # requeue: dlq empties, tasks land back on sims with retries reset
    assert main(["merlin-dlq", "--broker", url, "requeue",
                 "--queue", "sims"]) == 0
    assert "2 task(s) requeued" in capsys.readouterr().out
    check = FileBroker(str(tmp_path))
    assert check.qsize(("dlq.sims",)) == 0
    assert check.qsize(("sims",)) == 3  # the live task + 2 requeued
    seen = {}
    while True:
        lease = check.get(timeout=0.2, queues=("sims",))
        if lease is None:
            break
        seen[lease.task.id] = lease.task.retries
        check.ack(lease.tag)
    assert set(seen) == {"t-live", "t-dead0", "t-dead1"}
    assert seen["t-dead0"] == 0 and seen["t-dead1"] == 0


def test_status_snapshot_surfaces_shard_health():
    """status_snapshot forwards per-shard failover health when the broker
    exposes it (duck-typed on shard_health)."""
    class _FakeSharded(InMemoryBroker):
        def shard_health(self):
            return [{"shard": 0, "epoch": 1, "candidates": [
                {"endpoint": "tcp://a:1", "alive": False, "active": False},
                {"endpoint": "tcp://b:1", "alive": True, "active": True}]}]

    snap = status_snapshot(_FakeSharded())
    assert snap["shards"][0]["epoch"] == 1
    assert snap["shards"][0]["candidates"][1]["active"] is True
    assert "shards" not in status_snapshot(InMemoryBroker())
