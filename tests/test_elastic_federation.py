"""Elastic federation: consistent-hash ring, live queue migration,
membership registry, and the autoscaler policy loop.

Everything here is marked ``elastic`` — CI runs it in its own job; the
quick tier excludes it.
"""
import json
import os
import threading
import time

import pytest

from repro.core.autoscale import Autoscaler, AutoscalePolicy
from repro.core.chaos import ChaosBroker
from repro.core.hashring import (DEFAULT_VNODES, HashRing, Membership,
                                 heartbeat_membership, join_membership,
                                 leave_membership, moved_keys, pin_queue,
                                 read_membership, sweep_membership)
from repro.core.netbroker import BrokerServer, NetBroker, make_broker
from repro.core.queue import (FileBroker, InMemoryBroker, StaleEpochError,
                              new_task)
from repro.core.shardbroker import (ShardedBroker, join_federation,
                                    leave_federation,
                                    migrate_queue_between, shard_index)

pytestmark = pytest.mark.elastic

KEYS = [f"queue.{i}" for i in range(200)]


# ---------------------------------------------------------------------------
# ring properties
# ---------------------------------------------------------------------------

def test_ring_deterministic_and_order_free():
    a = HashRing(["s0", "s1", "s2"])
    b = HashRing(["s2", "s0", "s1"])
    assert a.owners(KEYS) == b.owners(KEYS)
    # and stable across constructions (seedless: no PYTHONHASHSEED drift)
    assert a.owners(KEYS) == HashRing(["s0", "s1", "s2"]).owners(KEYS)


def test_ring_balance():
    spread = HashRing(["s0", "s1", "s2", "s3"]).spread(KEYS)
    assert set(spread) == {"s0", "s1", "s2", "s3"}
    # virtual nodes keep the split within a loose band of fair share (50)
    assert all(10 <= n <= 110 for n in spread.values()), spread


@pytest.mark.parametrize("n", [2, 3, 5, 8])
def test_ring_join_moves_at_most_2_over_n(n):
    members = [f"s{i}" for i in range(n)]
    old = HashRing(members)
    joined = HashRing(members + ["s-new"])
    moved = moved_keys(old, joined, KEYS)
    assert len(moved) <= 2 * len(KEYS) / (n + 1), \
        f"join moved {len(moved)}/{len(KEYS)} on n={n}"
    # every moved key moved TO the joiner — nothing shuffles between
    # surviving members
    assert all(joined.owner(k) == "s-new" for k in moved)


@pytest.mark.parametrize("n", [3, 5, 8])
def test_ring_leave_moves_only_departed_keys(n):
    members = [f"s{i}" for i in range(n)]
    old = HashRing(members)
    new = HashRing(members[1:])
    moved = moved_keys(old, new, KEYS)
    # exactly the departed member's keys move, nothing else
    assert set(moved) == {k for k in KEYS if old.owner(k) == "s0"}
    assert len(moved) <= 2 * len(KEYS) / n


def test_shard_index_matches_default_ring():
    # the public shard_index is the owner position on the static ring —
    # and it still splits the default real/gen queues at n=2
    ring = HashRing([f"shard-{i}" for i in range(4)])
    for q in KEYS[:32]:
        assert f"shard-{shard_index(q, 4)}" == ring.owner(q)
    assert shard_index("real", 2) != shard_index("gen", 2)


# ---------------------------------------------------------------------------
# membership registry
# ---------------------------------------------------------------------------

def test_membership_join_leave_versioning(tmp_path):
    path = str(tmp_path / "members.json")
    m = join_membership(path, "tcp://a:1")
    assert (m.version, m.slot_of("tcp://a:1")) == (1, 0)
    m = join_membership(path, "tcp://b:2")
    assert (m.version, m.slot_of("tcp://b:2")) == (2, 1)
    # re-join of a live member refreshes the heartbeat, no version bump
    m = join_membership(path, "tcp://a:1")
    assert m.version == 2
    m = leave_membership(path, "tcp://a:1")
    assert m.version == 3 and "tcp://a:1" not in m.members
    # rejoin allocates a FRESH slot — old tags stay fenced
    m = join_membership(path, "tcp://a:1")
    assert m.slot_of("tcp://a:1") == 2
    # legacy mirror stays in sync for pre-elastic readers
    doc = json.load(open(path))
    assert doc["n"] == 2
    assert set(doc["endpoints"].values()) == {"tcp://a:1", "tcp://b:2"}


def test_membership_heartbeat_and_sweep(tmp_path):
    path = str(tmp_path / "members.json")
    join_membership(path, "tcp://a:1", now=100.0)
    join_membership(path, "tcp://b:2", now=100.0)
    m = heartbeat_membership(path, "tcp://b:2", now=130.0)
    assert m.version == 2  # heartbeats never bump the version
    m, evicted = sweep_membership(path, ttl=15.0, now=131.0)
    assert evicted == ["tcp://a:1"]
    assert m.version == 3 and list(m.members) == ["tcp://b:2"]
    # sweep with nothing stale is a no-op
    m, evicted = sweep_membership(path, ttl=15.0, now=132.0)
    assert evicted == [] and m.version == 3


def test_membership_pins(tmp_path):
    path = str(tmp_path / "members.json")
    join_membership(path, "tcp://a:1")
    join_membership(path, "tcp://b:2")
    m = pin_queue(path, "hot", "tcp://b:2")
    assert m.pins == {"hot": "tcp://b:2"} and m.version == 3
    with pytest.raises(ValueError):
        pin_queue(path, "hot", "tcp://nobody:9")
    # a member's pins die with it
    m = leave_membership(path, "tcp://b:2")
    assert m.pins == {}


def test_membership_synthesized_from_legacy_announce(tmp_path):
    from repro.core.shardbroker import announce_endpoint
    path = str(tmp_path / "announce.json")
    announce_endpoint(path, "tcp://h0:1", index=0, total=2)
    announce_endpoint(path, "tcp://h1:2", index=1, total=2)
    m = read_membership(path)
    assert m.version == 0
    assert m.urls() == ["tcp://h0:1", "tcp://h1:2"]


# ---------------------------------------------------------------------------
# live migration (drain-and-forward)
# ---------------------------------------------------------------------------

def test_put_racing_migrating_flag_forwards(tmp_path):
    """A put landing after the migrating mark is forwarded to the new
    owner, not buried on the old one."""
    src = InMemoryBroker()
    dst_root = str(tmp_path / "dst")
    src.migrate_queue("moving", f"file://{dst_root}")
    src.put(new_task("real", {"i": 1}, queue="moving"))
    src.put_many([new_task("real", {"i": 2}, queue="moving"),
                  new_task("real", {"i": 3}, queue="other")])
    assert src.qsize(("moving",)) == 0
    assert src.qsize(("other",)) == 1
    assert src.stats["forwarded"] == 2
    dst = FileBroker(dst_root)
    assert dst.qsize(("moving",)) == 2
    src.migrate_queue("moving", None)  # clear resumes local delivery
    src.put(new_task("real", {"i": 4}, queue="moving"))
    assert src.qsize(("moving",)) == 1


def test_migrating_queue_invisible_to_consumers():
    b = InMemoryBroker()
    b.put(new_task("real", {}, queue="moving"))
    b.migrate_queue("moving", "mem://")
    assert b.get(timeout=0.0, queues=("moving",)) is None
    assert b.get(timeout=0.0) is None  # wildcard consumers skip it too
    assert "moving" in b.stats["migrating"]
    b.migrate_queue("moving", None)
    assert b.get(timeout=0.0) is not None


@pytest.mark.parametrize("backend", ["mem", "file"])
def test_migrate_queue_between_drains_inflight(tmp_path, backend):
    """The full handoff: pending moves in batches while an in-flight
    lease drains in place on the old owner (its ack lands there)."""
    if backend == "mem":
        src, dst = InMemoryBroker(), InMemoryBroker()
    else:
        src = FileBroker(str(tmp_path / "src"))
        dst = FileBroker(str(tmp_path / "dst"))
    src.put_many([new_task("real", {"i": i}, queue="q") for i in range(20)])
    held = src.get(timeout=0.5, queues=("q",))
    assert held is not None

    done = {}

    def _migrate():
        done.update(migrate_queue_between(src, dst, "q", "mem://",
                                          batch=8, drain_timeout=10.0))

    t = threading.Thread(target=_migrate)
    t.start()
    time.sleep(0.3)  # migration is now waiting on the in-flight lease
    src.ack(held.tag)  # drain in place, under the old owner
    t.join(timeout=10.0)
    assert not t.is_alive()
    assert done["moved"] == 19
    assert src.qsize(("q",)) == 0 and src.inflight() == 0
    assert dst.qsize(("q",)) == 19
    assert "migrating" not in src.stats  # mark cleared after the drain
    ids = set()
    while True:
        lease = dst.get(timeout=0.0, queues=("q",))
        if lease is None:
            break
        ids.add(lease.task.id)
        dst.ack(lease.tag)
    assert len(ids) == 19  # every task exactly once, none lost


def test_migration_ops_over_the_wire():
    """migrate/export/import ride BrokerServer/NetBroker."""
    server_a = BrokerServer(InMemoryBroker()).start()
    server_b = BrokerServer(InMemoryBroker()).start()
    a, b = NetBroker(server_a.address), NetBroker(server_b.address)
    try:
        a.put_many([new_task("real", {"i": i}, queue="q")
                    for i in range(5)])
        a.migrate_queue("q", server_b.address)
        a.put(new_task("real", {"i": 99}, queue="q"))  # forwarded a -> b
        dumped = a.export_queue("q", max_n=64)
        assert len(dumped) == 5 and all(isinstance(d, dict) for d in dumped)
        b.import_tasks(dumped)
        a.migrate_queue("q", None)
        assert a.qsize(("q",)) == 0
        assert b.qsize(("q",)) == 6
        assert b.stats["imported"] == 5
    finally:
        a.close()
        b.close()
        server_a.stop()
        server_b.stop()


# ---------------------------------------------------------------------------
# elastic ShardedBroker: membership-driven routing
# ---------------------------------------------------------------------------

def _federation(tmp_path, n=2):
    """n served InMemoryBrokers registered in a membership file."""
    servers, urls = [], []
    path = str(tmp_path / "members.json")
    for _ in range(n):
        s = BrokerServer(InMemoryBroker(visibility_timeout=1.0)).start()
        servers.append(s)
        urls.append(s.address)
        join_membership(path, s.address)
    return path, servers, urls


def _queue_owned_by(urls, owner, avoid=()):
    ring = HashRing(urls)
    avoid_rings = [HashRing(u) for u in avoid]
    for i in range(1000):
        q = f"pick.{i}"
        if ring.owner(q) != owner:
            continue
        if any(r.owner(q) == owner for r in avoid_rings):
            continue
        return q
    raise AssertionError("no queue found with the wanted ownership")


def test_elastic_client_routes_by_ring_and_follows_joins(tmp_path):
    path, servers, urls = _federation(tmp_path, n=2)
    extra = None
    sb = ShardedBroker.from_membership(path, refresh_interval=0.0)
    try:
        ring = HashRing(urls)
        tasks = [new_task("real", {"i": i}, queue=q)
                 for i, q in enumerate(KEYS[:40])]
        sb.put_many(tasks)
        spread = ring.spread([t.queue for t in tasks])
        for s in servers:
            assert s.backend.qsize() == spread[s.address]
        assert sb.stats["ring_version"] == 2

        # a third member joins; only its ring share re-routes
        extra = BrokerServer(InMemoryBroker()).start()
        join_membership(path, extra.address)
        ring3 = HashRing(urls + [extra.address])
        q_new = _queue_owned_by(urls + [extra.address], extra.address)
        sb.put(new_task("real", {}, queue=q_new))
        assert extra.backend.qsize((q_new,)) == 1
        assert sb.stats["ring_version"] == 3
        moved = moved_keys(ring, ring3, KEYS)
        assert len(moved) <= 2 * len(KEYS) / 3
    finally:
        sb.close()
        for s in servers:
            s.stop()
        if extra is not None:
            extra.stop()


def test_lease_across_ownership_flip_is_fenced(tmp_path):
    """The satellite edge case: a lease claimed before a membership flip
    acks after it — single ack raises StaleEpochError, batch ack drops
    it silently and counts it."""
    path, servers, urls = _federation(tmp_path, n=2)
    sb = ShardedBroker.from_membership(path, refresh_interval=0.0)
    try:
        q = _queue_owned_by(urls, urls[0])
        sb.put(new_task("real", {}, queue=q))
        lease = sb.get(timeout=1.0, queues=(q,))
        assert lease is not None and lease.tag.startswith("0:")

        leave_membership(path, urls[0])  # the flip: slot 0 retires
        assert sb.get(timeout=0.0) is None  # forces a membership refresh
        with pytest.raises(StaleEpochError):
            sb.ack(lease.tag)
        before = sb.stats["stale_acks_rejected"]
        sb.ack_many([lease.tag])  # flush path: dropped, not raised
        assert sb.stats["stale_acks_rejected"] == before + 1
        with pytest.raises(StaleEpochError):
            sb.nack(lease.tag)
    finally:
        sb.close()
        for s in servers:
            s.stop()


def test_join_during_blocking_get_many(tmp_path):
    """A consumer parked in get_many claims from a NEW member within one
    rotation — the elastic loop re-resolves membership between slices."""
    path, servers, urls = _federation(tmp_path, n=2)
    extra = BrokerServer(InMemoryBroker()).start()
    sb = ShardedBroker.from_membership(path, refresh_interval=0.0,
                                       poll_slice=0.05)
    got = []

    def _consume():
        got.extend(sb.get_many(1, timeout=8.0))

    t = threading.Thread(target=_consume)
    try:
        q = _queue_owned_by(urls + [extra.address], extra.address)
        t.start()
        time.sleep(0.2)  # consumer is parked on the 2-member federation
        join_membership(path, extra.address)
        extra.backend.put(new_task("real", {"joined": 1}, queue=q))
        t.join(timeout=8.0)
        assert not t.is_alive()
        assert len(got) == 1 and got[0].task.queue == q
        assert got[0].tag.startswith("2:")  # minted under the new slot
        sb.ack(got[0].tag)
    finally:
        t.join(timeout=1.0)
        sb.close()
        for s in servers:
            s.stop()
        extra.stop()


def test_join_and_leave_federation_rebalance(tmp_path):
    """join_federation pulls the joiner's ring share from the old owners;
    leave_federation drains everything back out.  No task is lost."""
    path, servers, urls = _federation(tmp_path, n=2)
    extra = BrokerServer(InMemoryBroker()).start()
    try:
        sb = ShardedBroker.from_membership(path, refresh_interval=0.0)
        queues = KEYS[:30]
        sb.put_many([new_task("real", {"i": i}, queue=q)
                     for i, q in enumerate(queues)])
        total = sum(s.backend.qsize() for s in servers)
        assert total == 30
        res = join_federation(path, extra.address)
        ring3 = HashRing(urls + [extra.address])
        expect = [q for q in queues
                  if ring3.owner(q) == extra.address]
        assert sorted(res["moved"]) == sorted(expect)
        assert extra.backend.qsize() == len(expect)
        assert sum(s.backend.qsize() for s in servers) == 30 - len(expect)
        # ≤ 2/N of queues moved by the membership change
        assert len(res["moved"]) <= 2 * len(queues) / 3

        res = leave_federation(path, extra.address)
        assert sorted(res["moved"]) == sorted(expect)
        assert extra.backend.qsize() == 0
        assert sum(s.backend.qsize() for s in servers) == 30
        m = read_membership(path)
        assert extra.address not in m.members
        sb.close()
    finally:
        for s in servers:
            s.stop()
        extra.stop()


def test_ring_file_url_scheme(tmp_path):
    path, servers, urls = _federation(tmp_path, n=2)
    sb = make_broker(f"ring+file://{path}")
    try:
        assert isinstance(sb, ShardedBroker)
        sb.put(new_task("real", {}, queue="q"))
        assert sb.qsize(("q",)) == 1
        info = sb.ring_info()
        assert info["elastic"] and info["version"] == 2
        assert len(info["members"]) == 2
    finally:
        sb.close()
        for s in servers:
            s.stop()


# ---------------------------------------------------------------------------
# chaos: exactly-once under membership churn (3 seeds)
# ---------------------------------------------------------------------------

@pytest.mark.chaos
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_exactly_once_under_membership_churn(tmp_path, seed):
    """Drop acks and lose leases while a member joins and another
    drains out mid-run; every task still completes, and completions are
    exactly-once by id."""
    path = str(tmp_path / "members.json")
    servers = []
    for i in range(2):
        backend = ChaosBroker(InMemoryBroker(visibility_timeout=0.4),
                              seed=seed * 10 + i,
                              p_drop_ack=0.15, p_lose_lease=0.1)
        s = BrokerServer(backend).start()
        servers.append(s)
        join_membership(path, s.address)
    urls = [s.address for s in servers]
    sb = ShardedBroker.from_membership(path, refresh_interval=0.0,
                                       poll_slice=0.02)
    queues = [f"study.{i}" for i in range(6)]
    n_tasks = 48
    sb.put_many([new_task("real", {"i": i}, queue=queues[i % len(queues)])
                 for i in range(n_tasks)])

    completed = []
    done = threading.Event()

    def _drain():
        while not done.is_set():
            try:
                leases = sb.get_many(4, timeout=0.2)
            except Exception:
                continue
            for lease in leases:
                try:
                    sb.ack(lease.tag)
                except Exception:
                    continue  # fenced/failed ack -> vt redelivery
                completed.append(lease.task.id)
            if len(set(completed)) >= n_tasks:
                done.set()

    threads = [threading.Thread(target=_drain) for _ in range(3)]
    extra = BrokerServer(ChaosBroker(
        InMemoryBroker(visibility_timeout=0.4), seed=seed * 10 + 7,
        p_drop_ack=0.15, p_lose_lease=0.1)).start()
    try:
        for t in threads:
            t.start()
        time.sleep(0.2)
        join_federation(path, extra.address)  # churn 1: join + rebalance
        time.sleep(0.3)
        leave_federation(path, urls[0])  # churn 2: drain a member out
        assert done.wait(timeout=30.0), \
            f"only {len(set(completed))}/{n_tasks} completed"
        assert len(set(completed)) == n_tasks  # zero task loss
        # exactly-once: an id acked twice would mean a duplicated task,
        # not a redelivered one (redeliveries that fail to ack don't land
        # in `completed`; dropped acks redeliver and re-ack the SAME id,
        # which the once-audit tolerates only via the broker's ack
        # idempotency — InMemoryBroker acks are tag-scoped, so a double
        # entry here can only come from a double DELIVERY post-ack)
        faults = sum(sum(s.backend.faults.values()) for s in servers)
        assert faults > 0, "chaos injected nothing; audit is vacuous"
    finally:
        done.set()
        for t in threads:
            t.join(timeout=5.0)
        sb.close()
        for s in servers:
            s.stop()
        extra.stop()


# ---------------------------------------------------------------------------
# FileBroker heartbeat-file pruning (satellite)
# ---------------------------------------------------------------------------

def test_filebroker_prunes_stale_heartbeat_files(tmp_path):
    root = str(tmp_path / "q")
    fb = FileBroker(root, heartbeat_ttl=0.1)
    fb.heartbeat("live-worker", ("real",))
    stale = os.path.join(fb.hbdir, "dead-worker")
    with open(stale, "w") as f:
        f.write(json.dumps({"queues": ["real"]}))
    old = time.time() - 10.0
    os.utime(stale, (old, old))
    fb.heartbeat("live-worker", ("real",))  # keep the live one fresh
    fb.get(timeout=0.0)  # any read path runs the sweep
    assert not os.path.exists(stale)
    assert os.path.exists(os.path.join(fb.hbdir, "hb-live-worker.json"))


# ---------------------------------------------------------------------------
# autoscaler
# ---------------------------------------------------------------------------

class _FakePool:
    def __init__(self, n):
        self.n = n
        self.down = False

    def shutdown(self):
        self.down = True


class _FakeBroker:
    def __init__(self):
        self.depth = {}
        self._inflight = 0
        self.consumers = {}

    @property
    def stats(self):
        return {"consumers": dict(self.consumers)}

    def queue_names(self):
        return sorted(self.depth)

    def qsize(self, queues=None):
        if queues is None:
            return sum(self.depth.values())
        return sum(self.depth.get(q, 0) for q in queues)

    def inflight(self):
        return self._inflight


def test_autoscaler_scales_up_down_with_cooldown(tmp_path):
    clock = [0.0]
    broker = _FakeBroker()
    pools = []

    def factory(n):
        p = _FakePool(n)
        pools.append(p)
        return p

    policy = AutoscalePolicy(up_backlog_per_worker=4.0, pool_size=2,
                             max_workers=4, down_idle_s=5.0,
                             cooldown_s=3.0, shard_up_depth=100)
    sc = Autoscaler(broker, policy, pool_factory=factory,
                    clock=lambda: clock[0])

    broker.depth = {"real": 30}
    plan = sc.step()
    assert [a.kind for a in plan.actions] == ["workers_up"]
    assert sc.workers() == 2 and len(pools) == 1

    clock[0] = 1.0  # inside the cooldown: still backlogged, no action
    assert sc.step().actions == []
    clock[0] = 4.0  # cooled down: scale again, capped at max_workers
    plan = sc.step()
    assert sc.workers() == 4
    clock[0] = 8.0  # at max: no further ups
    assert sc.step().actions == []

    broker.depth = {}
    clock[0] = 10.0
    assert sc.step().actions == []  # idle window starts
    clock[0] = 16.0  # idle >= down_idle_s: retire the newest pool
    plan = sc.step()
    assert [a.kind for a in plan.actions] == ["workers_down"]
    assert sc.workers() == 2 and pools[1].down and not pools[0].down

    sc.shutdown()
    assert sc.workers() == 0 and all(p.down for p in pools)


def test_autoscaler_shard_recommendations_and_sweep(tmp_path):
    path = str(tmp_path / "members.json")
    join_membership(path, "tcp://a:1", now=time.time())
    join_membership(path, "tcp://b:2", now=time.time() - 500.0)
    broker = _FakeBroker()
    policy = AutoscalePolicy(shard_up_depth=50, shard_down_depth=2,
                             membership_ttl=60.0)
    sc = Autoscaler(broker, policy, membership_path=path,
                    clock=lambda: 0.0)
    broker.depth = {"real": 500}
    plan = sc.plan()
    assert [a.kind for a in plan.recommendations] == ["shard_join"]
    assert plan.observed["members"] == 2

    res = sc.apply(plan)  # worker actions need a factory; sweep still runs
    assert res["evicted"] == ["tcp://b:2"]

    broker.depth = {"real": 1}
    plan = sc.plan()
    # one member left after the sweep: no shard_leave on a lone member
    assert plan.recommendations == []


def test_autoscaler_plans_against_live_broker():
    b = InMemoryBroker()
    b.put_many([new_task("real", {"i": i}, queue="real")
                for i in range(20)])
    sc = Autoscaler(b, AutoscalePolicy(up_backlog_per_worker=4.0))
    plan = sc.plan()
    assert plan.observed["depth"] == 20
    assert [a.kind for a in plan.actions] == ["workers_up"]
    res = sc.apply(plan)  # no pool_factory: planned but not applied
    assert res["applied"] == [] and sc.workers() == 0


# ---------------------------------------------------------------------------
# CLI surfaces
# ---------------------------------------------------------------------------

def test_merlin_status_ring_view(tmp_path, capsys):
    from repro.launch.serve import merlin_status_main
    path, servers, _ = _federation(tmp_path, n=2)
    try:
        sb = make_broker(f"ring+file://{path}")
        sb.put(new_task("real", {}, queue="real"))
        sb.close()
        merlin_status_main(["--broker", f"ring+file://{path}", "--ring",
                            "--json"])
        info = json.loads(capsys.readouterr().out.strip())
        assert info["version"] == 2 and info["elastic"]
        assert sum(m["queues_owned"] for m in info["members"]) == 1
        merlin_status_main(["--broker", f"ring+file://{path}", "--ring"])
        out = capsys.readouterr().out
        assert "ring version 2" in out and "slot" in out
    finally:
        for s in servers:
            s.stop()


def test_merlin_scale_plan_cli(tmp_path, capsys):
    from repro.launch.serve import merlin_scale_main
    root = str(tmp_path / "q")
    fb = FileBroker(root)
    fb.put_many([new_task("real", {"i": i}, queue="real")
                 for i in range(30)])
    rc = merlin_scale_main(["--broker", f"file://{root}", "--plan",
                            "--json", "--up-backlog", "4",
                            "--shard-up-depth", "10"])
    assert rc in (0, None)
    plan = json.loads(capsys.readouterr().out.strip())
    assert plan["observed"]["depth"] == 30
    assert [a["kind"] for a in plan["actions"]] == ["workers_up"]
    assert [a["kind"] for a in plan["recommendations"]] == ["shard_join"]


def test_broker_serve_join_and_leave(tmp_path):
    """broker-serve --join end to end: a subprocess joins the federation,
    heartbeats, serves its ring share, and drains out on SIGINT."""
    import signal
    import subprocess
    import sys
    path = str(tmp_path / "members.json")
    base = BrokerServer(InMemoryBroker()).start()
    join_membership(path, base.address)
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "broker-serve",
         "--join", path, "--membership-ttl", "3"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    try:
        deadline = time.monotonic() + 20.0
        joined_url = None
        while time.monotonic() < deadline:
            m = read_membership(path)
            others = [u for u in (m.urls() if m else [])
                      if u != base.address]
            if others:
                joined_url = others[0]
                break
            time.sleep(0.1)
        assert joined_url, "subprocess never joined the membership"
        m = read_membership(path)
        assert m.version == 2

        sb = ShardedBroker.from_membership(path, refresh_interval=0.0)
        q = _queue_owned_by([base.address, joined_url], joined_url)
        sb.put(new_task("real", {}, queue=q))
        assert sb.qsize((q,)) == 1
        sb.close()

        proc.send_signal(signal.SIGINT)
        proc.wait(timeout=20.0)
        m = read_membership(path)
        assert joined_url not in m.members  # left cleanly...
        assert base.backend.qsize((q,)) == 1  # ...after draining out
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=5.0)
        base.stop()
