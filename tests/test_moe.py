"""MoE layer: impl equivalence, capacity semantics, router properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import moe as MOE


def make_cfg(**kw):
    base = dict(arch_id="t", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                head_dim=16, d_ff=64, vocab_size=128,
                superblock=(LayerSpec(mlp="moe"),), n_repeat=1,
                n_experts=8, top_k=2, d_ff_expert=16,
                compute_dtype="float32", param_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture
def setup():
    cfg = make_cfg()
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    return cfg, p, x


def test_sort_equals_gshard_when_no_drops(setup):
    cfg, p, x = setup
    cfg_nd = cfg.replace(capacity_factor=100.0)
    y1, a1 = MOE.moe_layer(p, x, cfg_nd)
    y2, a2 = MOE.moe_layer(p, x, cfg_nd.replace(moe_impl="sort"))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    assert np.isclose(float(a1["moe_lb_loss"]), float(a2["moe_lb_loss"]))


def test_sort_equals_gshard_with_drops(setup):
    """Same capacity rule -> identical drop set in both implementations."""
    cfg, p, x = setup
    cfg_d = cfg.replace(capacity_factor=0.5)
    y1, _ = MOE.moe_layer(p, x, cfg_d)
    y2, _ = MOE.moe_layer(p, x, cfg_d.replace(moe_impl="sort"))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_capacity_drops_zero_output_for_overflow(setup):
    cfg, p, x = setup
    # capacity ~0 -> (almost) everything dropped -> outputs ~ shared path only
    cfg0 = cfg.replace(capacity_factor=1e-9, n_shared_experts=0)
    y, _ = MOE.moe_layer(p, x, cfg0)
    # each expert still gets >= 8 slots (rounding floor); most tokens dropped
    dropped_norm = float(jnp.abs(y).mean())
    yfull, _ = MOE.moe_layer(p, x, cfg.replace(capacity_factor=100.0))
    assert dropped_norm < float(jnp.abs(yfull).mean())


def test_positions_in_expert_are_queue_positions():
    ids = jnp.array([[[0, 1], [0, 0], [1, 0]]])  # (G=1, T=3, k=2)
    pos = MOE._positions_in_expert(ids, 4)
    # expert 0 receives: t0s0 (0), t1s0 (1), t1s1 (2), t2s1 (3)
    assert pos[0, 0, 0] == 0 and pos[0, 1, 0] == 1 and pos[0, 1, 1] == 2
    assert pos[0, 2, 1] == 3
    # expert 1: t0s1 (0), t2s0 (1)
    assert pos[0, 0, 1] == 0 and pos[0, 2, 0] == 1


def test_router_aux_losses_behave(setup):
    cfg, p, x = setup
    _, aux = MOE.moe_layer(p, x, cfg)
    # balanced-ish at init: lb loss near 1.0 (its minimum) at uniform routing
    assert 0.8 < float(aux["moe_lb_loss"]) < 4.0
    assert float(aux["moe_z_loss"]) >= 0.0


def test_gradients_flow_through_both_impls(setup):
    cfg, p, x = setup
    for impl in ["gshard", "sort"]:
        c = cfg.replace(moe_impl=impl, capacity_factor=2.0)

        def loss(pp):
            y, aux = MOE.moe_layer(pp, x, c)
            return jnp.sum(y ** 2) + aux["moe_lb_loss"]

        g = jax.grad(loss)(p)
        gnorm = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
        assert np.isfinite(gnorm) and gnorm > 0, impl
