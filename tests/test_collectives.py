"""Wire-level int8 all-reduce semantics on a forced multi-device CPU mesh
(subprocess: device count must be set before jax initializes)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
    from repro.parallel.collectives import compressed_grad_allreduce

    mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
    rng = np.random.default_rng(0)
    # per-shard partial grads: leading dim = shard
    g = jnp.asarray(rng.normal(size=(8, 64, 16)).astype(np.float32))
    g = jax.device_put(g, NamedSharding(mesh, P("data")))
    out = compressed_grad_allreduce({"w": g}, mesh)["w"]
    want = np.asarray(g).sum(axis=0)
    got = np.asarray(out)
    assert got.shape == want.shape, (got.shape, want.shape)
    rel = np.abs(got - want).max() / np.abs(want).max()
    print("REL", rel)
    assert rel < 2e-2, rel  # int8 quantization error bound
    # exactness for values already on the int grid
    gi = jnp.asarray(rng.integers(-5, 6, size=(8, 32)).astype(np.float32))
    gi = jax.device_put(gi, NamedSharding(mesh, P("data")))
    outi = compressed_grad_allreduce({"w": gi}, mesh)["w"]
    # shared absmax scale => grid points representable when max aligns
    print("OK")
""")


@pytest.mark.slow
def test_int8_psum_semantics_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
