"""Surrogate inference gateway: the HTTP status-mapping contract
(200/400/401/404/429/503/504), continuous batching end-to-end against a
real trained snapshot, snapshot refresh over the wire, graceful drain,
and the ``merlin-serve`` CLI as a subprocess with SIGINT shutdown.

Everything here opens localhost HTTP sockets, so the whole module
carries the ``serve`` marker (its own CI job; run with ``-m serve``)."""
import http.client
import json
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.active import SurrogateSnapshot
from repro.core.bundler import Bundler
from repro.serve.gateway import SurrogateGateway

pytestmark = pytest.mark.serve

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _request(port, method, path, body=None, headers=None, timeout=30.0):
    """One request, fresh connection; returns (status, parsed-json)."""
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    try:
        payload = None if body is None else (
            body if isinstance(body, bytes) else json.dumps(body))
        conn.request(method, path, body=payload,
                     headers={"Content-Type": "application/json",
                              **(headers or {})})
        r = conn.getresponse()
        raw = r.read()
        return r.status, (json.loads(raw) if raw else {})
    finally:
        conn.close()


def _post(port, path, body=None, **kw):
    return _request(port, "POST", path, body=body or {}, **kw)


def _get(port, path, **kw):
    return _request(port, "GET", path, **kw)


class _StubSnapshot:
    """Snapshot double for control-flow tests (no jax, instant)."""

    def __init__(self, block=False):
        self.version = 1
        self.rows = 8
        self.dims = 3
        self.gate = threading.Event()
        self.block = block
        self.calls = []  # row counts per fused launch

    def predict(self, X):
        first = not self.calls
        self.calls.append(len(X))
        if self.block and first:
            assert self.gate.wait(15.0)
        return (np.zeros(len(X), np.float32),
                np.ones(len(X), np.float32))

    def wait_entered(self):
        for _ in range(2000):
            if self.calls:
                return
            time.sleep(0.005)
        raise AssertionError("gateway never reached predict")

    def refresh(self):
        return False


def _archive(root, n=64, dims=3, seed=0):
    """A tiny study archive with enough signal to train on."""
    rng = np.random.default_rng(seed)
    X = rng.random((n, dims)).astype(np.float32)
    y = np.sin(3.0 * X[:, 0]) + 0.5 * X[:, 1]
    Bundler(root).write_bundle(0, n, {"inputs": X,
                                      "yield": y.astype(np.float32)})
    return X, y


def _tiny_snapshot(root):
    return SurrogateSnapshot(root, n_members=2, hidden=16, steps=40)


# ---------------------------------------------------------------------------
# end-to-end against a real trained snapshot
# ---------------------------------------------------------------------------

def test_predict_end_to_end(tmp_path):
    X, _ = _archive(str(tmp_path))
    with SurrogateGateway(_tiny_snapshot(str(tmp_path)),
                          auth_token=None) as gw:
        st, health = _get(gw.port, "/healthz")
        assert st == 200 and health["ok"] and health["rows"] == 64
        # 2-D batch
        st, out = _post(gw.port, "/v1/predict",
                        {"points": X[:4].tolist()})
        assert st == 200
        assert len(out["mu"]) == 4 and len(out["sigma"]) == 4
        assert all(np.isfinite(out["mu"])) and all(
            s >= 0 for s in out["sigma"])
        assert out["version"] == 1
        # 1-D point promotes to a single row
        st, out = _post(gw.port, "/v1/predict",
                        {"points": X[0].tolist()})
        assert st == 200 and out["n"] == 1
        st, stats = _get(gw.port, "/v1/stats")
        assert st == 200
        assert stats["batcher"]["completed"] >= 2
        assert stats["http"]["status"].get("200", 0) >= 3


def test_calibrate_and_what_if(tmp_path):
    _archive(str(tmp_path))
    with SurrogateGateway(_tiny_snapshot(str(tmp_path))) as gw:
        st, out = _post(gw.port, "/v1/calibrate",
                        {"target": 0.5, "n_candidates": 64, "top_k": 3,
                         "seed": 7})
        assert st == 200
        cands = out["candidates"]
        assert len(cands) == 3
        # gateway returns candidates best-first
        gaps = [c["gap"] for c in cands]
        assert gaps == sorted(gaps)
        assert all(len(c["point"]) == 3 for c in cands)

        st, out = _post(gw.port, "/v1/what-if",
                        {"point": [0.5, 0.5, 0.5], "radius": 0.05,
                         "n_perturb": 8})
        assert st == 200
        nb = out["neighborhood"]
        assert nb["mu_min"] <= out["mu"] + 1.0  # sane, finite geometry
        assert nb["mu_min"] <= nb["mu_mean"] <= nb["mu_max"]
        assert np.isfinite(out["sigma"])


def test_refresh_folds_new_bundles(tmp_path):
    root = str(tmp_path)
    _archive(root)
    snap = _tiny_snapshot(root)
    with SurrogateGateway(snap) as gw:
        st, out = _post(gw.port, "/v1/refresh")
        assert st == 200 and out["refreshed"] is False  # nothing new yet
        rng = np.random.default_rng(1)
        Xn = rng.random((32, 3)).astype(np.float32)
        Bundler(root).write_bundle(
            64, 96, {"inputs": Xn,
                     "yield": Xn[:, 0].astype(np.float32)})
        st, out = _post(gw.port, "/v1/refresh")
        assert st == 200 and out["refreshed"] is True
        assert out["rows"] == 96 and out["version"] == 2
        # the served model is the new one
        st, out = _post(gw.port, "/v1/predict", {"points": Xn[0].tolist()})
        assert st == 200 and out["version"] == 2


# ---------------------------------------------------------------------------
# status-mapping contract (stub snapshot: no jax in the loop)
# ---------------------------------------------------------------------------

def test_bad_requests_get_400_and_unknown_routes_404():
    with SurrogateGateway(_StubSnapshot()) as gw:
        assert _post(gw.port, "/v1/predict", {})[0] == 400  # missing field
        assert _post(gw.port, "/v1/predict",
                     {"points": [[1, 2]]})[0] == 400  # wrong dims
        assert _post(gw.port, "/v1/predict",
                     {"points": [[1, 2, float("nan")]]})[0] == 400
        assert _post(gw.port, "/v1/predict",
                     body=b"{not json")[0] == 400
        assert _post(gw.port, "/v1/predict",
                     {"points": [[1, 2, 3]], "deadline_ms": -5})[0] == 400
        assert _post(gw.port, "/v1/nope", {})[0] == 404
        assert _get(gw.port, "/nope")[0] == 404
        # contract errors never reach the model
        assert _StubSnapshot.predict is not None
        assert gw.batcher.stats()["submitted"] == 0


def test_bearer_auth_guards_everything_but_healthz():
    with SurrogateGateway(_StubSnapshot(), auth_token="sekrit") as gw:
        ok = {"Authorization": "Bearer sekrit"}
        assert _get(gw.port, "/healthz")[0] == 200  # liveness stays open
        assert _post(gw.port, "/v1/predict",
                     {"points": [[1, 2, 3]]})[0] == 401
        assert _post(gw.port, "/v1/predict", {"points": [[1, 2, 3]]},
                     headers={"Authorization": "Bearer wrong"})[0] == 401
        assert _get(gw.port, "/v1/stats")[0] == 401
        st, _ = _post(gw.port, "/v1/predict", {"points": [[1, 2, 3]]},
                      headers=ok)
        assert st == 200
        assert _get(gw.port, "/v1/stats", headers=ok)[0] == 200


def test_shed_maps_to_429_with_retry_after():
    """max_inflight=1 with a launch in flight and one queued: the next
    request is shed before admission and told when to come back."""
    snap = _StubSnapshot(block=True)
    with SurrogateGateway(snap, max_inflight=1) as gw:
        results = []

        def post_one():
            results.append(_post(gw.port, "/v1/predict",
                                 {"points": [[1, 2, 3]]}))

        t1 = threading.Thread(target=post_one)
        t1.start()
        snap.wait_entered()  # t1's launch holds the batcher loop
        t2 = threading.Thread(target=post_one)
        t2.start()
        for _ in range(2000):  # wait until t2's request is queued
            if gw.batcher.stats()["queued"] >= 1:
                break
            time.sleep(0.005)
        conn = http.client.HTTPConnection("127.0.0.1", gw.port, timeout=10)
        try:
            conn.request("POST", "/v1/predict",
                         body=json.dumps({"points": [[1, 2, 3]]}),
                         headers={"Content-Type": "application/json"})
            r = conn.getresponse()
            assert r.status == 429
            assert r.getheader("Retry-After") == "1"
            r.read()
        finally:
            conn.close()
        snap.gate.set()
        t1.join(timeout=15)
        t2.join(timeout=15)
        assert sorted(st for st, _ in results) == [200, 200]  # shed cost
        assert gw.batcher.stats()["shed"] == 1  # no admitted request


def test_deadline_maps_to_504_without_executing():
    snap = _StubSnapshot(block=True)
    with SurrogateGateway(snap) as gw:
        results = []

        def hold():
            results.append(_post(gw.port, "/v1/predict",
                                 {"points": [[1, 2, 3]]}))

        t1 = threading.Thread(target=hold)
        t1.start()
        snap.wait_entered()
        t2 = threading.Thread(target=lambda: results.append(
            _post(gw.port, "/v1/predict",
                  {"points": [[9, 9, 9]], "deadline_ms": 50})))
        t2.start()
        time.sleep(0.2)  # the 50ms deadline passes while queued
        snap.gate.set()
        t1.join(timeout=15)
        t2.join(timeout=15)
        statuses = sorted(st for st, _ in results)
        assert statuses == [200, 504]
        assert gw.batcher.stats()["expired"] == 1
        assert snap.calls == [1]  # the doomed rows never executed


def test_drain_returns_503_and_completes_admitted():
    """stop(drain=True): requests already admitted complete with 200
    while new arrivals are refused with 503."""
    snap = _StubSnapshot(block=True)
    gw = SurrogateGateway(snap).start()
    results = []

    def post_one():
        results.append(_post(gw.port, "/v1/predict",
                             {"points": [[1, 2, 3]]}))

    t1 = threading.Thread(target=post_one)
    t1.start()
    snap.wait_entered()
    t2 = threading.Thread(target=post_one)
    t2.start()
    for _ in range(2000):
        if gw.batcher.stats()["queued"] >= 1:
            break
        time.sleep(0.005)
    stopped = []
    stopper = threading.Thread(
        target=lambda: stopped.append(gw.stop(drain=True, timeout=15)))
    stopper.start()
    for _ in range(2000):  # draining flag flips before the drain wait
        if gw.stats()["draining"]:
            break
        time.sleep(0.005)
    st, body = _post(gw.port, "/v1/predict", {"points": [[1, 2, 3]]})
    assert st == 503 and "drain" in body["error"]
    snap.gate.set()
    t1.join(timeout=15)
    t2.join(timeout=15)
    stopper.join(timeout=20)
    assert stopped == [True]  # backlog fully drained
    assert sorted(s for s, _ in results) == [200, 200]


# ---------------------------------------------------------------------------
# merlin-serve CLI (subprocess, SIGINT drain)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_merlin_serve_cli_serves_and_drains_on_sigint(tmp_path):
    _archive(str(tmp_path / "study"))
    port_file = str(tmp_path / "serve.port")
    env = {**os.environ,
           "PYTHONPATH": "src" + os.pathsep
           + os.environ.get("PYTHONPATH", "")}
    env.pop("REPRO_AUTH_TOKEN", None)
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "merlin-serve",
         "--study", str(tmp_path / "study"), "--port", "0",
         "--port-file", port_file,
         "--members", "2", "--hidden", "16", "--steps", "40"],
        cwd=REPO_ROOT, env=env, stdout=subprocess.PIPE, text=True)
    try:
        deadline = time.monotonic() + 120  # includes snapshot training
        while not os.path.exists(port_file):
            assert proc.poll() is None, "merlin-serve died during startup"
            assert time.monotonic() < deadline, "server did not come up"
            time.sleep(0.05)
        with open(port_file) as f:
            port = int(f.read())
        st, health = _get(port, "/healthz")
        assert st == 200 and health["rows"] == 64
        st, out = _post(port, "/v1/predict",
                        {"points": [[0.1, 0.2, 0.3]]})
        assert st == 200 and len(out["mu"]) == 1
        proc.send_signal(signal.SIGINT)
        stdout, _ = proc.communicate(timeout=30)
        assert proc.returncode == 0
        events = [json.loads(line) for line in stdout.splitlines()
                  if line.startswith("{")]
        kinds = [e["event"] for e in events]
        assert kinds[0] == "listening" and kinds[-1] == "drained"
        assert events[0]["mode"] == "continuous"
        assert events[-1]["clean"] is True
        assert events[-1]["stats"]["batcher"]["completed"] >= 1
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)
