import os
import sys

# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device; only launch/dryrun.py forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # not installed in this image (see requirements-dev.txt): register the
    # seeded-PRNG shim so the property tests still collect and run
    import _hypothesis_fallback
    _hypothesis_fallback.install()

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
