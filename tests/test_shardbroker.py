"""ShardedBroker federation, backpressure (BrokerFull), consumer
heartbeats, unified queue-name validation, and worker ack-retry.

``shard``-marked tests exercise the multi-endpoint federation layer;
those that also open real sockets carry ``net`` as well (``-m 'not net'``
still deselects them in restricted sandboxes)."""
import threading
import time

import numpy as np
import pytest

from repro.core import (Broker, BrokerError, BrokerFull, BrokerServer,
                        Bundler, FileBroker, InMemoryBroker, MerlinRuntime,
                        NetBroker, ShardedBroker, StaleEpochError, Step,
                        StudySpec, Task, WorkerPool, make_broker, new_task)
from repro.core.hierarchy import HierarchyCfg
from repro.core.shardbroker import shard_index

SHARD = pytest.mark.shard
NET = pytest.mark.net


# ---------------------------------------------------------------------------
# queue-name validation (satellite: all backends fail fast identically)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", ["a__b", "a/b", ".hidden", ""])
def test_invalid_queue_name_rejected_at_task_creation(bad):
    """The same study spec must fail the same way on every backend — at
    Task creation, not at FileBroker's first put mid-run."""
    import json
    with pytest.raises(ValueError):
        new_task("real", {}, queue=bad)
    with pytest.raises(ValueError):
        Task(id="x", kind="real", payload={}, queue=bad)
    wire = json.dumps({"id": "x", "kind": "real", "payload": {},
                       "priority": 0, "queue": bad, "retries": 0,
                       "enqueued_at": 0.0})
    with pytest.raises(ValueError):
        Task.from_json(wire)


def test_invalid_queue_name_mutated_after_creation(tmp_path):
    """Backstop: a task whose queue was mutated post-construction still
    fails fast at put time on the FileBroker."""
    t = new_task("real", {})
    t.queue = "sneaky/../escape"
    fb = FileBroker(str(tmp_path / "q"))
    with pytest.raises(ValueError):
        fb.put(t)


def test_valid_queue_names_still_work():
    for ok in ("sims", "gen-2", "ml.train", "a_b", "BENCH7"):
        assert new_task("real", {}, queue=ok).queue == ok


# ---------------------------------------------------------------------------
# sharded routing
# ---------------------------------------------------------------------------

def _two_mem_shards(**kw):
    return ShardedBroker([InMemoryBroker(), InMemoryBroker()], **kw)


@SHARD
def test_sharded_broker_satisfies_protocol():
    assert isinstance(_two_mem_shards(), Broker)


@SHARD
def test_stable_hash_and_override_routing():
    sb = _two_mem_shards(queue_shards={"pinned": 1})
    assert sb.shard_for("pinned") == 1
    for q in ("real", "gen", "sims", "anything"):
        assert sb.shard_for(q) == shard_index(q, 2)
    # the hash is stable across instances (different processes would agree)
    sb2 = _two_mem_shards()
    assert all(sb.shard_for(q) == sb2.shard_for(q)
               for q in ("real", "gen", "sims"))
    with pytest.raises(ValueError):
        _two_mem_shards(queue_shards={"q": 5})


@SHARD
def test_put_routes_whole_queue_to_one_shard():
    sb = _two_mem_shards()
    for i in range(10):
        sb.put(new_task("real", {"i": i}, queue="sims"))
    owner = sb.shard_for("sims")
    assert sb.shards[owner].qsize() == 10
    assert sb.shards[1 - owner].qsize() == 0


@SHARD
def test_get_many_fans_only_across_owning_shards():
    sb = _two_mem_shards(queue_shards={"a": 0, "b": 1})
    sb.put_many([new_task("real", {"q": q}, queue=q)
                 for q in ("a", "b") for _ in range(3)])
    # single-shard subscription: pass-through, only shard 0 is touched
    leases = sb.get_many(10, timeout=1, queues=("a",))
    assert len(leases) == 3
    assert all(l.task.queue == "a" for l in leases)
    assert sb.shards[1].inflight() == 0
    # multi-shard subscription drains both
    rest = sb.get_many(10, timeout=1, queues=("a", "b"))
    assert sorted(l.task.queue for l in rest) == ["b", "b", "b"]
    sb.ack_many([l.tag for l in leases + rest])
    assert sb.idle()


@SHARD
def test_ack_nack_route_back_to_owning_shard():
    sb = _two_mem_shards(queue_shards={"a": 0, "b": 1})
    sb.put(new_task("real", {"x": 1}, queue="b"))
    lease = sb.get(timeout=1)
    assert lease.tag.startswith("1:")
    sb.nack(lease.tag)
    again = sb.get(timeout=1)
    assert again.task.retries == 1
    sb.ack(again.tag)
    assert sb.idle()
    assert sb.shards[1].stats["acked"] == 1
    assert sb.shards[0].stats["acked"] == 0
    with pytest.raises(ValueError):
        sb.ack("not-a-sharded-tag")


@SHARD
def test_merged_views_and_stats():
    sb = _two_mem_shards(queue_shards={"a": 0, "b": 1})
    sb.put_many([new_task("real", {}, queue="a") for _ in range(2)]
                + [new_task("real", {}, queue="b") for _ in range(3)])
    assert sb.qsize() == 5
    assert sb.qsize(("a",)) == 2
    assert sb.queue_names() == ["a", "b"]
    lease = sb.get(timeout=1, queues=("b",))
    assert sb.inflight() == 1
    assert len(sb.inflight_tasks()) == 1
    st = sb.stats
    assert st["enqueued"] == 5
    assert len(st["shards"]) == 2
    sb.ack(lease.tag)


@SHARD
def test_blocking_get_sees_put_on_any_shard():
    """A consumer parked across both shards wakes for a task appearing on
    either one (rotation of the blocking slice)."""
    sb = _two_mem_shards(queue_shards={"a": 0, "b": 1}, poll_slice=0.02)
    got = []

    def consume():
        got.append(sb.get(timeout=5, queues=("a", "b")))

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.1)
    sb.put(new_task("real", {"late": 1}, queue="b"))
    t.join(timeout=5)
    assert got and got[0] is not None and got[0].task.payload == {"late": 1}
    assert sb.get_many(2, timeout=0.1) == []  # empty timeout path


@SHARD
def test_visibility_timeout_routes_to_owner():
    sb = _two_mem_shards(queue_shards={"fast": 0, "slow": 1})
    sb.set_visibility_timeout("fast", 0.1)
    sb.put(new_task("real", {}, queue="fast"))
    sb.put(new_task("real", {}, queue="slow"))
    l1 = sb.get(timeout=1, queues=("fast",))
    l2 = sb.get(timeout=1, queues=("slow",))
    assert l1 and l2
    back = sb.get(timeout=2)  # only the fast lease expires (default vt 60)
    assert back is not None and back.task.queue == "fast"
    sb.ack_many([back.tag, l2.tag])


@SHARD
@NET
def test_sharded_study_over_two_broker_servers(tmp_path):
    """End to end: a study whose gen and real queues live on DIFFERENT
    broker server processes' backends, driven via MerlinRuntime(broker=
    [url, url]) — the first topology where ensemble traffic does not
    funnel through one broker process."""
    s1 = BrokerServer(InMemoryBroker()).start()
    s2 = BrokerServer(InMemoryBroker()).start()
    results = Bundler(str(tmp_path / "res"))
    try:
        rt = MerlinRuntime(broker=[s1.address, s2.address],
                           workspace=str(tmp_path / "ws"),
                           hierarchy=HierarchyCfg(max_fanout=4, bundle=8))
        assert isinstance(rt.broker, ShardedBroker)
        # default queues split across the two shards (crc32 hash)
        assert rt.broker.shard_for("real") != rt.broker.shard_for("gen")
        rt.register("sim", lambda ctx: results.write_bundle(
            ctx.lo, ctx.hi, {"y": ctx.sample_block[:, 0]}))
        spec = StudySpec(name="sharded", steps=[Step(name="sim", fn="sim")])
        with WorkerPool(rt, n_workers=3, batch=2) as pool:
            sid = rt.run(spec, np.arange(64, dtype=np.float32).reshape(64, 1))
            assert rt.wait(sid, timeout=90)
            assert pool.drain(timeout=30)
        assert np.allclose(np.sort(results.load_all()["y"]), np.arange(64))
        # both shards actually carried traffic
        per_shard = [st["enqueued"] for st in rt.broker.stats["shards"]]
        assert all(e > 0 for e in per_shard), per_shard
        rt.broker.close()
    finally:
        s1.stop()
        s2.stop()


@SHARD
def test_make_broker_shard_url_and_list(tmp_path):
    sb = make_broker(["mem://", "mem://"])
    assert isinstance(sb, ShardedBroker) and len(sb.shards) == 2
    with pytest.raises(ValueError):
        make_broker("shard://")


# ---------------------------------------------------------------------------
# replica failover + epoch fencing
# ---------------------------------------------------------------------------

@SHARD
@NET
def test_make_broker_shard_url_with_replica_pipes():
    """shard://h1:p1|h1r:p1r,h2:p2 — '|' names replica candidates within
    one shard, ',' separates shards."""
    servers = [BrokerServer(InMemoryBroker()).start() for _ in range(3)]
    try:
        hp = [s.address[len("tcp://"):] for s in servers]
        sb = make_broker(f"shard://{hp[0]}|{hp[1]},{hp[2]}")
        assert isinstance(sb, ShardedBroker)
        assert len(sb.shards) == 2  # replicas don't add shards
        assert len(sb._candidates[0]) == 2
        assert len(sb._candidates[1]) == 1
        sb.put(new_task("real", {"x": 1}, queue="sims"))
        lease = sb.get(timeout=1, queues=("sims",))
        assert lease.task.payload == {"x": 1}
        sb.ack(lease.tag)
        sb.close()
    finally:
        for s in servers:
            s.stop()


@SHARD
@NET
def test_primary_death_fails_over_and_fences_stale_acks(tmp_path):
    """Kill a primary mid-study: ownership moves to the shard's replica
    under a bumped epoch, acks minted against the dead primary are
    rejected as stale, resume() restores the tasks that died with the
    primary, and the study still completes exactly once."""
    prim = [BrokerServer(InMemoryBroker()).start() for _ in range(2)]
    repl = [BrokerServer(InMemoryBroker()).start() for _ in range(2)]
    results = Bundler(str(tmp_path / "res"))
    sb = ShardedBroker(
        [[prim[0].address, repl[0].address],
         [prim[1].address, repl[1].address]],
        reconnect_timeout=0.5)
    try:
        rt = MerlinRuntime(broker=sb, workspace=str(tmp_path / "ws"),
                           hierarchy=HierarchyCfg(max_fanout=4, bundle=8))
        rt.register("sim", lambda ctx: results.write_bundle(
            ctx.lo, ctx.hi, {"y": ctx.sample_block[:, 0]}))
        spec = StudySpec(name="fo", steps=[Step(name="sim", fn="sim")])
        # enqueue with no workers running: the root gen task sits on the
        # gen queue's owning primary, and dies with it below
        sid = rt.run(spec, np.arange(64, dtype=np.float32).reshape(64, 1))
        kidx = sb.shard_for("gen")
        # a lease minted under epoch 0 of the soon-to-die primary
        lease = sb.get(timeout=2, queues=("gen",))
        assert lease is not None and lease.tag.startswith(f"{kidx}:0:")
        prim[kidx].stop()
        # any call touching the dead shard triggers the failover
        sb.qsize()
        assert sb._epochs[kidx] == 1
        assert sb._failovers >= 1
        # the pre-failover lease is fenced: its ack must NOT land on the
        # replica (same inner tag could alias a fresh lease there)
        with pytest.raises(StaleEpochError):
            sb.ack(lease.tag)
        # ...but the batched flush path drops stale tags silently so a
        # worker's retried-forever ack flush can never wedge
        sb.ack_many([lease.tag])
        assert sb.stats["stale_acks_rejected"] >= 2
        # health view: the dead primary shows dead, the replica is active
        health = sb.shard_health()
        assert health[kidx]["epoch"] == 1
        cands = health[kidx]["candidates"]
        assert cands[0]["alive"] is False and cands[0]["active"] is False
        assert cands[1]["alive"] is True and cands[1]["active"] is True
        # replicas are warm standbys, not content replicas: the queued
        # root task died with the primary — resume() re-enqueues it from
        # the filesystem truth, now landing on the replica
        rt.resume(sid)
        with WorkerPool(rt, n_workers=3, batch=2) as pool:
            assert rt.wait(sid, timeout=90)
            assert pool.drain(timeout=30)
        assert np.allclose(np.sort(results.load_all()["y"]), np.arange(64))
        # exactly-once: one stage_done, one study_done, 8 distinct bundles
        evs = [e for e in rt.journal.replay()
               if e.get("study") == sid]
        assert len([e for e in evs if e["ev"] == "stage_done"]) == 1
        assert len([e for e in evs if e["ev"] == "study_done"]) == 1
        ranges = sorted((e["lo"], e["hi"]) for e in evs
                        if e["ev"] == "bundle_done")
        assert ranges == [(i, i + 8) for i in range(0, 64, 8)]
    finally:
        sb.close()
        for s in prim + repl:
            s.stop()


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------

@pytest.fixture(params=["mem", "file"])
def bounded_broker(request, tmp_path):
    def make(**kw):
        kw.setdefault("max_queue_depth", 4)
        kw.setdefault("put_timeout", 0.25)
        if request.param == "mem":
            return InMemoryBroker(**kw)
        return FileBroker(str(tmp_path / "q"), **kw)
    return make


def test_put_many_blocks_then_raises_broker_full(bounded_broker):
    b = bounded_broker()
    t0 = time.monotonic()
    with pytest.raises(BrokerFull):
        b.put_many([new_task("real", {"i": i}) for i in range(10)])
    assert time.monotonic() - t0 >= 0.2  # it blocked before raising
    assert b.qsize() == 4  # admitted up to the bound, no further


def test_put_blocks_until_consumer_drains(bounded_broker):
    """With a consumer draining, a batch far larger than the bound goes
    through — backpressure throttles, it does not fail."""
    b = bounded_broker(put_timeout=5.0)
    n = 20
    done = []

    def consume():
        while len(done) < n:
            lease = b.get(timeout=2)
            if lease is None:
                return
            done.append(lease.task.payload["i"])
            b.ack(lease.tag)

    t = threading.Thread(target=consume)
    t.start()
    b.put_many([new_task("real", {"i": i}) for i in range(n)])
    t.join(timeout=10)
    assert sorted(done) == list(range(n))
    assert b.idle()


def test_redelivery_is_exempt_from_backpressure(bounded_broker):
    """nack/expiry must never wedge on a full queue."""
    b = bounded_broker()
    b.put_many([new_task("real", {"i": i}) for i in range(4)])  # at bound
    lease = b.get(timeout=1)
    b.nack(lease.tag)  # queue is full again; must not block or raise
    assert b.qsize() == 4


@NET
def test_broker_full_is_typed_over_the_wire():
    """put_many against a bounded remote backend blocks (server-side) at
    max_queue_depth, then the structured error maps back to BrokerFull
    client-side — not a generic BrokerError."""
    server = BrokerServer(InMemoryBroker(max_queue_depth=3,
                                         put_timeout=0.25)).start()
    nb = NetBroker(server.address)
    try:
        t0 = time.monotonic()
        with pytest.raises(BrokerFull):
            nb.put_many([new_task("real", {"i": i}) for i in range(10)])
        assert time.monotonic() - t0 >= 0.2  # it blocked before raising
        assert nb.qsize() == 3  # admitted up to the bound, no further
        # the queue still serves normally afterwards
        lease = nb.get(timeout=1)
        assert lease is not None
        nb.ack(lease.tag)
    finally:
        nb.close()
        server.stop()


@SHARD
@NET
def test_backpressure_throttles_workers_without_killing_them(tmp_path):
    """End to end over tcp://: gen expansion into a bounded real queue
    hits BrokerFull; the expanding worker throttles and retries
    (stats["throttled"] > 0) instead of dying, and once a consumer drains
    the real queue every child is delivered."""
    from repro.core import hierarchy as H
    backend = InMemoryBroker(max_queue_depth=6, put_timeout=0.2)
    server = BrokerServer(backend).start()
    rt = MerlinRuntime(broker=NetBroker(server.address),
                       workspace=str(tmp_path / "ws"))
    try:
        # workers subscribe ONLY to gen: nobody drains the real queue yet,
        # so the 16-child expansion must overflow the depth-6 bound
        with WorkerPool(rt, n_workers=2, queues=("gen",)) as pool:
            root = H.root_task(
                "bp", "0", 64, HierarchyCfg(max_fanout=16, bundle=4),
                extra={"real_queue": "real", "gen_queue": "gen"})
            rt.broker.put(root)
            deadline = time.monotonic() + 20
            while time.monotonic() < deadline:
                if pool.stats()["throttled"] >= 1:
                    break
                time.sleep(0.05)
            assert pool.stats()["throttled"] >= 1, "BrokerFull never hit"
            assert all(w.is_alive() for w in pool.workers)  # throttled, alive
            # now drain the real queue: capacity frees, the worker's retry
            # completes the expansion (duplicates are possible and safe)
            client = NetBroker(server.address)
            seen = set()
            deadline = time.monotonic() + 30
            # drain until all 16 distinct children arrived AND one retry
            # fully succeeded (late put_many retries keep producing safe
            # duplicates until then, so keep draining while we wait)
            while time.monotonic() < deadline and \
                    (len(seen) < 16 or pool.stats()["gen"] < 1):
                for lease in client.get_many(8, timeout=0.3,
                                             queues=("real",)):
                    seen.add(tuple(lease.task.payload["samples"]))
                    client.ack(lease.tag)
            assert len(seen) == 16, f"only {len(seen)}/16 children arrived"
            assert all(w.is_alive() for w in pool.workers)
            assert pool.stats()["gen"] >= 1  # the expansion DID complete
            client.close()
        rt.broker.close()
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# consumer heartbeats
# ---------------------------------------------------------------------------

@pytest.fixture(params=["mem", "file"])
def hb_broker(request, tmp_path):
    if request.param == "mem":
        return InMemoryBroker(heartbeat_ttl=0.3)
    return FileBroker(str(tmp_path / "q"), heartbeat_ttl=0.3)


def test_heartbeat_registry_reports_live_consumers(hb_broker):
    b = hb_broker
    b.heartbeat("w1", ("sims",))
    b.heartbeat("w2", ("sims", "ml"))
    b.heartbeat("w3", None)  # all-queues subscription reported under "*"
    c = b.stats["consumers"]
    assert c == {"sims": 2, "ml": 1, "*": 1}
    time.sleep(0.4)  # > ttl: everyone ages out
    b.heartbeat("w2", ("ml",))  # except the one that keeps beating
    assert b.stats["consumers"] == {"ml": 1}


def test_filebroker_heartbeats_visible_across_instances(tmp_path):
    """Heartbeats are queue state: another instance on the same directory
    (the operator's monitoring process) sees the same live view."""
    b1 = FileBroker(str(tmp_path / "q"), heartbeat_ttl=5.0)
    b2 = FileBroker(str(tmp_path / "q"), heartbeat_ttl=5.0)
    b1.heartbeat("alloc1:w0", ("sims",))
    assert b2.stats["consumers"] == {"sims": 1}


@NET
def test_worker_pool_heartbeats_surface_in_stats(tmp_path):
    """Workers heartbeat through the wire op; stats["consumers"] replaces
    the connection-count guess with a live per-queue view."""
    server = BrokerServer(InMemoryBroker(heartbeat_ttl=5.0)).start()
    rt = MerlinRuntime(broker=NetBroker(server.address),
                       workspace=str(tmp_path / "ws"))
    try:
        with WorkerPool(rt, n_workers=3, queues=("real", "gen")):
            deadline = time.monotonic() + 10
            while time.monotonic() < deadline:
                c = rt.broker.stats["consumers"]
                if c.get("real", 0) >= 3 and c.get("gen", 0) >= 3:
                    break
                time.sleep(0.05)
            else:
                pytest.fail(f"heartbeats never showed 3 workers: {c}")
        rt.broker.close()
    finally:
        server.stop()


@SHARD
def test_sharded_heartbeat_reaches_owning_shards():
    sb = _two_mem_shards(queue_shards={"a": 0, "b": 1})
    sb.heartbeat("w1", ("a", "b"))
    assert sb.shards[0].stats["consumers"] == {"a": 1}
    assert sb.shards[1].stats["consumers"] == {"b": 1}
    assert sb.stats["consumers"] == {"a": 1, "b": 1}
    sb.heartbeat("w2", None)  # all-queues: every shard hears it
    assert all(s.stats["consumers"].get("*") == 1 for s in sb.shards)
    # merged view must not double-count the same consumer across shards
    assert sb.stats["consumers"]["*"] == 1


# ---------------------------------------------------------------------------
# worker ack retry (satellite: a broker blip must not drop collected acks)
# ---------------------------------------------------------------------------

class _FlakyAckBroker:
    """Delegates to an InMemoryBroker but fails the first ``fail_n``
    ack_many calls — a transient blip between lease and ack."""

    def __init__(self, fail_n=1):
        self._inner = InMemoryBroker(visibility_timeout=30.0)
        self._fail_n = fail_n
        self.failed_acks = 0

    def ack_many(self, tags):
        if self._fail_n > 0:
            self._fail_n -= 1
            self.failed_acks += 1
            raise BrokerError("injected ack blip")
        return self._inner.ack_many(tags)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_worker_retries_acks_after_broker_blip(tmp_path):
    """The acks collected before the blip land on the NEXT iteration
    (acks are idempotent) instead of being dropped and forcing N lease
    expiries + re-executions; retried acks are counted."""
    broker = _FlakyAckBroker(fail_n=1)
    rt = MerlinRuntime(broker=broker, workspace=str(tmp_path / "ws"),
                       hierarchy=HierarchyCfg(max_fanout=4, bundle=4))
    done = []
    rt.register("sim", lambda ctx: done.append((ctx.lo, ctx.hi)))
    spec = StudySpec(name="ackretry", steps=[Step(name="sim", fn="sim")])
    with WorkerPool(rt, n_workers=1, batch=2) as pool:
        sid = rt.run(spec, np.zeros((16, 1), np.float32))
        assert rt.wait(sid, timeout=60)
        # wait until the retried acks actually landed, not just until the
        # study finished (the flush happens on the next worker iteration)
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:
            if broker.failed_acks and pool.stats()["acks_retried"] > 0 \
                    and broker._inner.inflight() == 0:
                break
            time.sleep(0.05)
        stats = pool.stats()
    assert broker.failed_acks == 1
    assert stats["acks_retried"] >= 1
    assert broker._inner.inflight() == 0  # nothing left to expire
    # vt=30s and nothing redelivered: every range ran exactly once
    covered = sorted(i for lo, hi in done for i in range(lo, hi))
    assert covered == list(range(16))
    assert broker._inner.stats["redelivered"] == 0


# ---------------------------------------------------------------------------
# per-queue depth routing + endpoint discovery file
# ---------------------------------------------------------------------------

@SHARD
def test_sharded_set_max_queue_depth_routes_to_owner():
    sb = _two_mem_shards(queue_shards={"gen": 0, "sims": 1})
    for s in sb.shards:
        s._put_timeout = 0.2
    sb.set_max_queue_depth("gen", 1)
    sb.put(new_task("gen", {}, queue="gen"))
    with pytest.raises(BrokerFull):
        sb.put(new_task("gen", {}, queue="gen"))
    # the override landed ONLY on gen's owning shard
    for _ in range(5):
        sb.put(new_task("real", {}, queue="sims"))
    assert sb.shards[0]._depth_queue == {"gen": 1}
    assert sb.shards[1]._depth_queue == {}


@SHARD
def test_announce_and_read_endpoints_ordered(tmp_path):
    """Announce entries merge (locked, atomic) and read back in shard-index
    order regardless of announce order."""
    from repro.core.shardbroker import announce_endpoint, read_endpoints
    path = str(tmp_path / "shards.json")
    announce_endpoint(path, "tcp://h2:2", index=1, total=2)
    announce_endpoint(path, "tcp://h1:1", index=0, total=2)
    urls, n = read_endpoints(path)
    assert urls == ["tcp://h1:1", "tcp://h2:2"]
    assert n == 2
    # re-announcing (a restarted server on a new port) replaces its slot
    announce_endpoint(path, "tcp://h2:22", index=1, total=2)
    urls, _ = read_endpoints(path)
    assert urls == ["tcp://h1:1", "tcp://h2:22"]


@SHARD
@NET
def test_discover_drops_dead_endpoints_when_size_undeclared(tmp_path):
    """Un-announced (dead) endpoints from a previous federation run must
    not be assembled into the shard list when no size is declared."""
    from repro.core.shardbroker import announce_endpoint, discover_shards
    path = str(tmp_path / "shards.json")
    live = BrokerServer(InMemoryBroker()).start()
    try:
        dead_url = "tcp://127.0.0.1:1"  # reserved port: nothing listens
        announce_endpoint(path, dead_url)          # "previous run"
        announce_endpoint(path, live.address)      # current run
        sb = discover_shards(path, timeout=5.0)
        assert len(sb.shards) == 1
        sb.put(new_task("real", {"ok": 1}, queue="q"))
        lease = sb.get(timeout=1, queues=("q",))
        assert lease and lease.task.payload == {"ok": 1}
        sb.ack(lease.tag)
        sb.close()
    finally:
        live.stop()


@SHARD
def test_discover_shards_waits_for_declared_size(tmp_path):
    from repro.core.queue import BrokerUnavailable
    from repro.core.shardbroker import announce_endpoint, discover_shards
    path = str(tmp_path / "shards.json")
    announce_endpoint(path, "mem://", index=0, total=2)
    # only 1 of the declared 2 endpoints announced: discovery times out
    with pytest.raises(BrokerUnavailable):
        discover_shards(path, timeout=0.3)
    announce_endpoint(path, "mem://", index=1, total=2)
    sb = discover_shards(path, timeout=1.0)
    assert len(sb.shards) == 2


@SHARD
@NET
def test_shard_file_url_end_to_end(tmp_path):
    """broker-serve --announce + make_broker('shard+file://...'): clients
    assemble the federation from the discovery file and route normally."""
    from repro.core.shardbroker import announce_endpoint
    servers = [BrokerServer(InMemoryBroker()).start() for _ in range(2)]
    try:
        path = str(tmp_path / "shards.json")
        for i, s in enumerate(servers):
            announce_endpoint(path, s.address, index=i, total=2)
        sb = make_broker(f"shard+file://{path}")
        assert isinstance(sb, ShardedBroker) and len(sb.shards) == 2
        sb.put(new_task("real", {"x": 1}, queue="sims"))
        lease = sb.get(timeout=1, queues=("sims",))
        assert lease.task.payload == {"x": 1}
        sb.ack(lease.tag)
        assert sb.idle()
        # routing agreement: the queue landed on the crc32-owned shard
        owner = sb.shard_for("sims")
        assert servers[owner].backend.stats["enqueued"] == 1
        sb.close()
    finally:
        for s in servers:
            s.stop()


@SHARD
@NET
def test_broker_serve_announce_flag(tmp_path):
    """The --announce flag publishes the bound endpoint for discovery."""
    import json as _json
    import os as _os
    import subprocess as _subprocess
    import sys as _sys
    from repro.core.shardbroker import read_endpoints
    root = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
    env = dict(_os.environ)
    env["PYTHONPATH"] = _os.pathsep.join(
        [_os.path.join(root, "src")]
        + [p for p in env.get("PYTHONPATH", "").split(_os.pathsep) if p])
    ann = str(tmp_path / "ann.json")
    proc = _subprocess.Popen(
        [_sys.executable, "-m", "repro.launch.serve", "broker-serve",
         "--backend", "mem", "--port", "0", "--shard-of", "0/1",
         "--announce", ann],
        stdout=_subprocess.PIPE, text=True, env=env)
    try:
        line = _json.loads(proc.stdout.readline())
        assert line["event"] == "listening"
        deadline = time.monotonic() + 10
        urls, n = [], None
        while time.monotonic() < deadline and not urls:
            urls, n = read_endpoints(ann)
            time.sleep(0.05)
        assert urls == [f"tcp://127.0.0.1:{line['port']}"]
        assert n == 1
        nb = make_broker(f"shard+file://{ann}")
        nb.put(new_task("real", {"ok": 1}, queue="q"))
        lease = nb.get(timeout=2, queues=("q",))
        assert lease and lease.task.payload == {"ok": 1}
        nb.ack(lease.tag)
        nb.close()
    finally:
        proc.terminate()
        proc.wait(timeout=10)
