"""Per-step failure policy enforcement: all four ``on_failure`` modes
(retry-to-poison, dead_letter, skip, halt_study) plus the halted-study
passive drain, driven through real workers against an in-memory broker.
"""
import time

import numpy as np

from repro.core.hierarchy import HierarchyCfg
from repro.core.queue import InMemoryBroker, dlq_queue_name
from repro.core.runtime import MerlinRuntime
from repro.core.spec import Step, StudySpec
from repro.core.worker import WorkerPool


def _poll(cond, timeout=30.0, every=0.02):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(every)
    return False


def _rt(tmp_path, **kw):
    return MerlinRuntime(broker=InMemoryBroker(), workspace=str(tmp_path),
                         hierarchy=HierarchyCfg(max_fanout=4, bundle=4),
                         **kw)


def _always_fail(calls=None):
    def fn(ctx):
        if calls is not None:
            calls.append(1)
        raise RuntimeError("always")
    return fn


def test_on_failure_retry_exhausts_to_poison(tmp_path):
    rt = _rt(tmp_path)
    calls = []
    rt.register("boom", _always_fail(calls))
    spec = StudySpec(name="p", steps=[
        Step(name="boom", fn="boom", max_retries=1, on_failure="retry")])
    with WorkerPool(rt, n_workers=1) as pool:
        study = rt.run(spec, samples=np.zeros((4, 2), np.float32))
        assert _poll(lambda: pool.stats()["failed"] >= 1)
        assert _poll(lambda: rt.broker.idle())
    # delivered, nacked once (budget 1), redelivered, then given up as
    # poison and acked away — the broker saw exactly one redelivery
    # (call counts are not audited: the fused->per-task fallback legally
    # executes a failing delivery more than once)
    assert calls
    assert rt.broker.stats["redelivered"] == 1
    assert rt.dag_state(study)["state"]["s0/c0"]["status"] == "failed"
    assert not rt.study_done(study)


def test_on_failure_dead_letter_parks_task_on_dlq(tmp_path):
    rt = _rt(tmp_path)
    rt.register("boom", _always_fail())
    spec = StudySpec(name="d", steps=[
        Step(name="boom", fn="boom", max_retries=0,
             on_failure="dead_letter")])
    with WorkerPool(rt, n_workers=1) as pool:
        rt.run(spec, samples=np.zeros((4, 2), np.float32))
        assert _poll(lambda: pool.stats()["dead_lettered"] >= 1)
        assert _poll(lambda: rt.broker.idle())
    dlq = dlq_queue_name(rt.real_queue)
    assert rt.broker.qsize([dlq]) == 1
    # wildcard consumption never sees the DLQ: the mainline is clean
    assert rt.broker.qsize() == 0
    assert rt.broker.get(queues=None) is None
    # ...but explicit addressing reaches it, payload intact
    lease = rt.broker.get(queues=[dlq])
    assert lease is not None and lease.task.kind == "real"
    assert "study" in lease.task.payload
    evs = [e["ev"] for e in rt.journal.replay()]
    assert "task_dead_lettered" in evs


def test_on_failure_skip_completes_study_without_executing(tmp_path):
    rt = _rt(tmp_path)
    joined = []
    rt.register("boom", _always_fail())
    rt.register("post", lambda ctx: joined.append((ctx.lo, ctx.hi)))
    spec = StudySpec(name="s", steps=[
        Step(name="boom", fn="boom", max_retries=0, on_failure="skip"),
        Step(name="post", fn="post", depends=("boom",),
             over_samples=False)])
    with WorkerPool(rt, n_workers=2) as pool:
        study = rt.run(spec, samples=np.zeros((8, 2), np.float32))
        # skip records the bundles as complete, so children unlock and
        # the study reaches done despite the parent never succeeding
        assert rt.wait(study, timeout=60)
        pool.drain(timeout=30)
        assert pool.stats()["skipped"] >= 1
    assert joined  # the child actually ran
    skipped = [e for e in rt.journal.replay() if e["ev"] == "task_skipped"]
    assert len(skipped) == 2  # 8 samples / bundle 4
    state = rt.dag_state(study)["state"]
    assert all(v["status"] == "done" for v in state.values())


def test_on_failure_halt_study_stops_the_graph(tmp_path):
    rt = _rt(tmp_path)
    rt.register("boom", _always_fail())
    rt.register("post", lambda ctx: None)
    spec = StudySpec(name="h", steps=[
        Step(name="boom", fn="boom", max_retries=0,
             on_failure="halt_study"),
        Step(name="post", fn="post", depends=("boom",),
             over_samples=False)])
    with WorkerPool(rt, n_workers=1) as pool:
        study = rt.run(spec, samples=np.zeros((4, 2), np.float32))
        # wait() reports failure fast instead of burning the timeout
        assert rt.wait(study, timeout=60) is False
        assert _poll(lambda: rt.broker.idle())
    assert rt.study_halted(study)
    halts = [e for e in rt.journal.replay() if e["ev"] == "study_halt"]
    assert len(halts) == 1 and "exhausted retries" in halts[0]["reason"]
    state = rt.dag_state(study)["state"]
    # the downstream instance never ran and never will
    assert state["s1/c0"]["status"] == "halted"
    assert not rt.study_done(study)


def test_halted_study_tasks_are_drained_not_executed(tmp_path):
    rt = _rt(tmp_path)
    ran = []
    rt.register("sim", lambda ctx: ran.append(1))
    spec = StudySpec(name="dr", steps=[Step(name="sim", fn="sim")])
    # enqueue first, halt second, start workers last: every queued task
    # belongs to a halted study and must be acked away unexecuted
    study = rt.run(spec, samples=np.zeros((16, 2), np.float32))
    assert rt.halt_study(study, reason="operator stop")
    assert rt.halt_study(study) is False  # idempotent once-marker
    with WorkerPool(rt, n_workers=2) as pool:
        assert _poll(lambda: rt.broker.idle())
        assert pool.stats()["halted_drained"] >= 1
    assert ran == []
    state = rt.dag_state(study)["state"]
    assert state["s0/c0"]["status"] == "halted"
