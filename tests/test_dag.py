"""Task-graph IR: compilation (fusion, edges, instance projection),
validation errors, and DAG execution end-to-end — diamonds, per-combo vs
funneled edges, per-node queue routing, all three execution handlers,
persisted node state, and published sample sets."""
import os

import numpy as np
import pytest

from repro.core.dag import compile_dag
from repro.core.handlers import MockScheduler, SchedulerJobHandler
from repro.core.runtime import MerlinRuntime
from repro.core.spec import SpecError, Step, StudySpec
from repro.core.worker import WorkerPool


def _diamond_spec(**join_kw):
    return StudySpec(name="dia", steps=[
        Step(name="prep", fn="prep"),
        Step(name="left", fn="left", depends=("prep",)),
        Step(name="right", fn="right", depends=("prep",)),
        Step(name="join", fn="join", depends=("left", "right"),
             over_samples=False, **join_kw)])


# ---------------------------------------------------------------------------
# compilation
# ---------------------------------------------------------------------------

def test_diamond_compiles_with_fan_out_and_fan_in():
    dag = compile_dag(_diamond_spec())
    assert [n.name for n in dag.nodes] == ["prep", "left", "right", "join"]
    assert dag.kinds() == ["parallel", "parallel", "parallel", "single"]
    # prep fans out to both branches; join fans in from both
    assert dag.instance_children(0, 0) == [(1, 0), (2, 0)]
    assert sorted(dag.instance_parents(3, 0)) == [(1, 0), (2, 0)]
    assert dag.indegree(3, 0) == 2
    assert dag.roots() == [(0, 0)]


def test_chain_fusion_stops_at_fan_out():
    # prep cannot fuse into left: prep has out-degree 2
    dag = compile_dag(_diamond_spec())
    assert all(len(n.steps) == 1 for n in dag.nodes)


def test_linear_chain_fuses_into_one_node():
    spec = StudySpec(name="ch", steps=[
        Step(name="a", fn="a"),
        Step(name="b", fn="b", depends=("a",)),
        Step(name="c", fn="c", depends=("b",))])
    dag = compile_dag(spec)
    assert len(dag.nodes) == 1
    assert dag.nodes[0].name == "a+b+c"


def test_fusion_respects_queue_and_handler_boundaries():
    spec = StudySpec(name="q", steps=[
        Step(name="a", fn="a"),
        Step(name="b", fn="b", depends=("a",), queue="other")])
    assert len(compile_dag(spec).nodes) == 2
    spec2 = StudySpec(name="h", steps=[
        Step(name="a", fn="a"),
        Step(name="b", cmd="true", depends=("a",))])
    assert len(compile_dag(spec2).nodes) == 2


def test_instance_projection_and_matched_edges():
    # parent varies over METRO only; child over METRO x SCEN: each child
    # instance depends on exactly the parent instance sharing its METRO
    spec = StudySpec(name="p", steps=[
        Step(name="cal", fn="cal", params=("M",)),
        Step(name="fc", fn="fc", depends=("cal",), params=("M", "S"))],
        parameters={"M": ["a", "b"], "S": [1, 2, 3]})
    dag = compile_dag(spec)
    cal, fc = dag.nodes
    assert len(cal.instances) == 2 and len(fc.instances) == 6
    for j, inst in enumerate(fc.instances):
        parents = dag.instance_parents(1, j)
        assert len(parents) == 1
        (pn, pi), = parents
        assert cal.instances[pi]["M"] == inst["M"]


def test_funnel_edge_collects_all_parent_instances():
    spec = StudySpec(name="f", steps=[
        Step(name="sim", fn="sim", params=("M",)),
        Step(name="all", fn="all", depends=("sim_*",), over_samples=False)],
        parameters={"M": ["a", "b", "c"]})
    dag = compile_dag(spec)
    assert dag.indegree(1, 0) == 3


def test_self_dependency_rejected():
    spec = StudySpec(name="s", steps=[
        Step(name="a", fn="a", depends=("a",))])
    with pytest.raises(SpecError):
        compile_dag(spec)


def test_duplicate_dependency_rejected():
    spec = StudySpec(name="dup", steps=[
        Step(name="a", fn="a"),
        Step(name="b", fn="b", depends=("a", "a"))])
    with pytest.raises(SpecError, match="duplicate"):
        compile_dag(spec)


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

def test_diamond_runs_end_to_end_with_state_epochs(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path))
    order = []
    for name in ("prep", "left", "right", "join"):
        rt.register(name, (lambda n: lambda ctx: order.append(n))(name))
    study = None
    with WorkerPool(rt, n_workers=2) as pool:
        study = rt.run(_diamond_spec(), samples=np.zeros((4, 2), np.float32))
        assert rt.wait(study, timeout=60)
        pool.drain(timeout=30)
    assert order[0] == "prep" and order[-1] == "join"
    assert set(order) == {"prep", "left", "right", "join"}
    state = rt.dag_state(study)["state"]
    assert all(v["status"] == "done" for v in state.values())
    # completion epochs respect the graph order
    ep = {k: v["epoch"] for k, v in state.items()}
    assert ep["s0/c0"] < ep["s1/c0"] < ep["s3/c0"]
    assert ep["s0/c0"] < ep["s2/c0"] < ep["s3/c0"]


def test_per_node_queue_routing(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path))
    seen = []
    rt.register("a", lambda ctx: seen.append("a"))
    rt.register("b", lambda ctx: seen.append("b"))
    spec = StudySpec(name="routed", steps=[
        Step(name="a", fn="a", queue="sims"),
        Step(name="b", fn="b", depends=("a_*",), over_samples=False,
             queue="post")])
    with WorkerPool(rt, n_workers=2) as pool:
        study = rt.run(spec, samples=np.zeros((2, 1), np.float32))
        assert rt.wait(study, timeout=60)
        pool.drain(timeout=30)
    assert seen == ["a", "b"]
    acked = rt.broker.stats["acked_by_queue"]
    assert acked.get("sims", 0) >= 1 and acked.get("post", 0) >= 1


def test_all_three_handlers_in_one_graph(tmp_path):
    """fn (in-process engine path) -> cmd (local subprocess) -> cmd via the
    mock batch scheduler, chained so each handler's output gates the
    next."""
    rt = MerlinRuntime(workspace=str(tmp_path))
    rt.register_handler(SchedulerJobHandler(
        scheduler=MockScheduler(hold_s=0.05), poll_s=0.01, timeout=30))
    ran = []
    rt.register("seed_fn", lambda ctx: ran.append((ctx.lo, ctx.hi)))
    spec = StudySpec(name="tri", steps=[
        Step(name="seed", fn="seed_fn"),
        Step(name="local", cmd="echo local $(SAMPLE_LO) > local.txt",
             depends=("seed_*",), over_samples=False),
        Step(name="batch", cmd="echo batch > batch.txt",
             depends=("local",), over_samples=False, handler="scheduler",
             resources={"nodes": 1})])
    with WorkerPool(rt, n_workers=2) as pool:
        study = rt.run(spec, samples=np.zeros((4, 2), np.float32))
        assert rt.wait(study, timeout=60)
        pool.drain(timeout=30)
    assert ran  # fn handler ran
    # each cmd step wrote its artifact in its node workspace
    found = {os.path.basename(p)
             for root, _, files in os.walk(tmp_path) for p in files}
    assert "local.txt" in found and "batch.txt" in found
    assert rt.handlers["scheduler"].scheduler.submitted == 1


def test_zip_parameters_run_per_combo(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path))
    combos = []
    rt.register("s", lambda ctx: combos.append(
        (ctx.combo["CFG"], ctx.combo["SEED"])))
    spec = StudySpec(name="z", steps=[Step(name="s", fn="s")],
                     parameters={"CFG%zip": ["small", "large"],
                                 "SEED%zip": [11, 17]})
    with WorkerPool(rt, n_workers=2) as pool:
        study = rt.run(spec, samples=np.zeros((2, 1), np.float32))
        assert rt.wait(study, timeout=60)
        pool.drain(timeout=30)
    assert sorted(set(combos)) == [("large", 17), ("small", 11)]


def test_published_sample_set_feeds_downstream_node(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path))
    got = {}

    def producer(ctx):
        ctx.publish_samples("picked", np.full((3, 2), 7.0, np.float32))

    def consumer(ctx):
        got["block"] = np.array(ctx.sample_block)
        got["n"] = ctx.hi - ctx.lo

    rt.register("producer", producer)
    rt.register("consumer", consumer)
    spec = StudySpec(name="pub", steps=[
        Step(name="produce", fn="producer", over_samples=False),
        Step(name="consume", fn="consumer", depends=("produce",),
             sample_set="picked")])
    with WorkerPool(rt, n_workers=2) as pool:
        study = rt.run(spec, samples=np.zeros((2, 1), np.float32))
        assert rt.wait(study, timeout=60)
        pool.drain(timeout=30)
    # consumer iterated the PUBLISHED set (3 samples of 7s), not default
    assert got and np.all(got["block"] == 7.0)


def test_run_requires_registered_handler(tmp_path):
    rt = MerlinRuntime(workspace=str(tmp_path), handlers={})
    spec = StudySpec(name="nh", steps=[Step(name="a", fn="a")])
    rt.register("a", lambda ctx: None)
    with pytest.raises(RuntimeError, match="handler"):
        rt.run(spec, samples=np.zeros((2, 1), np.float32))
