"""Hot-path performance machinery: bucketed compile cache, coalesced bundle
execution, scanned surrogate training, incremental archive loads, FileBroker
contention hardening — the regression fences for the fused ensemble path."""
import math
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ensemble as E
from repro.core.active import Surrogate, _mlp_apply, _mlp_init, train_surrogate
from repro.core.bundler import Bundler
from repro.core.hierarchy import HierarchyCfg
from repro.core.queue import FileBroker, new_task
from repro.core.runtime import MerlinRuntime
from repro.core.spec import Step, StudySpec
from repro.core.worker import WorkerPool


def _toy_sim(u, rng):
    """Cheap deterministic-per-seed simulator (fresh fn per test => fresh
    process-wide cache key)."""
    return {"v": u.sum() + jax.random.normal(rng) * 1e-3,
            "inputs": u}


# ---------------------------------------------------------------------------
# bucketed compile cache
# ---------------------------------------------------------------------------

def test_bucketed_compile_count_is_log_bounded():
    def sim(u, rng):
        return {"v": u * 2.0, "s": jax.random.normal(rng)}

    ex = E.EnsembleExecutor(sim)
    rng = np.random.default_rng(0)
    sizes = [1, 2, 3, 4, 5, 6, 7, 9, 11, 13, 16, 21, 27, 5, 3, 27]
    before = E.trace_count()
    lo = 0
    for s in sizes:
        out = ex.run_bundle(lo, lo + s, rng.random((s, 3)).astype(np.float32))
        assert out["v"].shape == (s, 3)  # padding sliced away
        lo += s
    # 13 distinct ragged sizes, but compiles bounded by the bucket schedule
    assert E.trace_count() - before <= math.ceil(math.log2(max(sizes))) + 1
    assert ex.stats["samples"] == sum(sizes)
    assert ex.stats["launches"] == len(sizes)


def test_shared_cache_across_executors():
    def sim(u, rng):
        return {"v": u + 1.0}

    rng = np.random.default_rng(0)
    E.EnsembleExecutor(sim).run_bundle(0, 8, rng.random((8, 2)).astype(np.float32))
    before = E.trace_count()
    # a fresh executor (new bundler, new iteration, new study) reuses the
    # process-wide compiled program: zero new traces
    E.EnsembleExecutor(sim).run_bundle(8, 16, rng.random((8, 2)).astype(np.float32))
    assert E.trace_count() == before


def test_bucketed_results_match_unbucketed(tmp_path):
    rng = np.random.default_rng(3)
    block = rng.random((5, 4)).astype(np.float32)
    b1 = Bundler(str(tmp_path / "a"))
    b2 = Bundler(str(tmp_path / "b"))
    E.EnsembleExecutor(_toy_sim, b1).run_bundle(10, 15, block)
    E.EnsembleExecutor(_toy_sim, b2, bucketed=False,
                       share_cache=False).run_bundle(10, 15, block)
    d1, d2 = b1.load_all(), b2.load_all()
    assert set(d1) == set(d2)
    for k in d1:
        np.testing.assert_allclose(d1[k], d2[k], rtol=1e-6)


# ---------------------------------------------------------------------------
# coalesced execution
# ---------------------------------------------------------------------------

def _run_study(workspace: str, batch: int, samples: np.ndarray):
    rt = MerlinRuntime(workspace=workspace,
                       hierarchy=HierarchyCfg(max_fanout=8, bundle=4))
    bundler = Bundler(os.path.join(workspace, "res"))
    ex = E.EnsembleExecutor(_toy_sim, bundler)
    rt.register("sim", ex.step_fn())
    spec = StudySpec(name="co", steps=[Step(name="sim", fn="sim")])
    with WorkerPool(rt, n_workers=1, batch=batch):
        sid = rt.run(spec, samples)
        assert rt.wait(sid, timeout=120)
    return rt, bundler


def test_coalesced_execution_matches_per_task(tmp_path):
    samples = np.random.default_rng(7).random((24, 4)).astype(np.float32)
    rt1, b1 = _run_study(str(tmp_path / "seq"), 1, samples)     # per-task
    rt2, b2 = _run_study(str(tmp_path / "coal"), 16, samples)   # coalesced
    d1, d2 = b1.load_all(), b2.load_all()
    assert set(d1) == set(d2)
    for k in d1:
        np.testing.assert_allclose(d1[k], d2[k], rtol=1e-6,
                                   err_msg=f"key {k} diverged under coalescing")
    # on-disk layout preserved: one bundle file per original leaf task
    files1 = sorted(f for _, _, fs in os.walk(b1.root) for f in fs)
    files2 = sorted(f for _, _, fs in os.walk(b2.root) for f in fs)
    assert files1 == files2
    # per-sub-bundle idempotency markers all exist in the coalesced run
    study = next(s for s in rt2._specs)
    for lo in range(0, 24, 4):
        assert rt2.counters.once_exists(f"{study}/exec/s0/c0/{lo}_{lo + 4}")


def test_coalesced_poison_task_falls_back_per_task(tmp_path):
    """One failing sub-task must not sink its batch-mates."""
    rt = MerlinRuntime(workspace=str(tmp_path),
                       hierarchy=HierarchyCfg(max_fanout=8, bundle=2))
    done = []

    def step(ctx):
        # poison whenever the (4,6) sub-task is present: fails the fused
        # batch AND every per-task retry of (4,6), so batch-mates can only
        # complete through the runtime's per-task fallback
        if any(tuple(r) == (4, 6) for r in ctx.sub_ranges):
            raise RuntimeError("poison")
        done.append((ctx.lo, ctx.hi))

    rt.register("step", step)
    spec = StudySpec(name="p", steps=[Step(name="step", fn="step")])
    with WorkerPool(rt, n_workers=1, batch=8):
        rt.run(spec, np.zeros((8, 1), np.float32))
        deadline = time.monotonic() + 30
        covered = set()
        while time.monotonic() < deadline:
            covered = set()
            for lo, hi in done:
                covered.update(range(lo, hi))
            if covered >= set(range(8)) - {4, 5}:
                break
            time.sleep(0.05)
    # every non-poison sample executed despite the poison batch-mate
    assert covered >= set(range(8)) - {4, 5}


# ---------------------------------------------------------------------------
# scanned surrogate training
# ---------------------------------------------------------------------------

def _train_reference(X, y, n_members=3, hidden=64, steps=60, lr=3e-3, seed=0):
    """The seed's eager per-member loop (ground truth for parity)."""
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32)

    def loss_fn(p):
        return jnp.mean((_mlp_apply(p, X) - y) ** 2)

    members = []
    for m in range(n_members):
        rng = jax.random.PRNGKey(seed * 131 + m)
        p = _mlp_init(rng, [X.shape[1], hidden, hidden, 1])
        mom = jax.tree.map(jnp.zeros_like, p)
        vel = jax.tree.map(jnp.zeros_like, p)
        for _ in range(steps):
            g = jax.grad(loss_fn)(p)
            mom = jax.tree.map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, mom, g)
            vel = jax.tree.map(lambda v_, g_: 0.999 * v_ + 0.001 * g_ ** 2,
                               vel, g)
            p = jax.tree.map(
                lambda p_, m_, v_: p_ - lr * m_ / (jnp.sqrt(v_) + 1e-8),
                p, mom, vel)
        members.append(p)
    return Surrogate(members)


def test_scanned_training_matches_eager_loop():
    rng = np.random.default_rng(5)
    X = rng.random((37, 4)).astype(np.float32)  # 37: forces masked padding
    y = (X[:, 0] - 0.3 * X[:, 1] ** 2).astype(np.float32)
    ref = _train_reference(X, y, steps=60)
    new = train_surrogate(X, y, steps=60)
    grid = rng.random((50, 4)).astype(np.float32)
    mu_ref, sd_ref = ref.predict(grid)
    mu_new, sd_new = new.predict(grid)
    np.testing.assert_allclose(mu_new, mu_ref, atol=2e-3)
    np.testing.assert_allclose(sd_new, sd_ref, atol=2e-3)
    # member parameters themselves agree (same init, same update rule)
    for pr, pn in zip(ref.params_list, new.params_list):
        for lr_, ln_ in zip(pr, pn):
            np.testing.assert_allclose(np.asarray(ln_["w"]),
                                       np.asarray(lr_["w"]), atol=2e-3)


def test_train_surrogate_single_compile_across_sizes():
    """Row-bucketing: dataset growth inside one bucket reuses the compile."""
    rng = np.random.default_rng(6)
    X = rng.random((70, 3)).astype(np.float32)
    y = X.sum(1).astype(np.float32)
    s1 = train_surrogate(X[:65], y[:65], steps=30)
    s2 = train_surrogate(X, y, steps=30)  # 65 and 70 both pad to 128
    for s in (s1, s2):
        mu, sd = s.predict(X)
        assert mu.shape == (70,) and sd.shape == (70,)


# ---------------------------------------------------------------------------
# incremental archive loads
# ---------------------------------------------------------------------------

def test_load_all_serves_cache_and_sees_new_bundles(tmp_path):
    b = Bundler(str(tmp_path))
    rng = np.random.default_rng(0)
    b.write_bundle(0, 4, {"y": rng.random(4).astype(np.float32)})
    first = b.load_all()
    again = b.load_all()  # unchanged tree: cached concatenation
    np.testing.assert_array_equal(first["y"], again["y"])
    b.write_bundle(4, 8, {"y": rng.random(4).astype(np.float32)})
    grown = b.load_all()
    assert list(grown["_sample_ids"]) == list(range(8))
    # aggregation rewrites files; the cache must follow, not go stale
    b.aggregate_all()
    agg = b.load_all()
    np.testing.assert_array_equal(agg["y"], grown["y"])


def test_load_since_under_concurrent_writers(tmp_path):
    reader = Bundler(str(tmp_path))
    writer = Bundler(str(tmp_path))
    n_bundles, width = 40, 5

    def write():
        for i in range(n_bundles):
            lo = i * width
            writer.write_bundle(lo, lo + width,
                                {"y": np.full(width, i, np.float32)})
            time.sleep(0.001)

    t = threading.Thread(target=write)
    t.start()
    seen = []
    cursor = None
    deadline = time.monotonic() + 60
    while time.monotonic() < deadline:
        delta, cursor = reader.load_since(cursor)
        if delta:
            seen.extend(int(i) for i in delta["_sample_ids"])
        if len(seen) >= n_bundles * width and not t.is_alive():
            break
        time.sleep(0.002)
    t.join()
    delta, cursor = reader.load_since(cursor)
    if delta:
        seen.extend(int(i) for i in delta["_sample_ids"])
    # every sample delivered exactly once across the cursor chain
    assert sorted(seen) == list(range(n_bundles * width))


# ---------------------------------------------------------------------------
# FileBroker contention (stale-index rename races)
# ---------------------------------------------------------------------------

def test_filebroker_contention_claims_exactly_once(tmp_path):
    root = str(tmp_path / "q")
    producer = FileBroker(root)
    n = 120
    producer.put_many([new_task("real", {"i": i}) for i in range(n)])
    claimed = [[] for _ in range(3)]
    brokers = []

    def drain(k):
        # a long rescan throttle: without forced rescans after stale-claim
        # races, dry spells under contention would starve this consumer
        b = FileBroker(root, rescan_interval=5.0)
        brokers.append(b)
        while True:
            lease = b.get(timeout=0.5)
            if lease is None:
                return
            claimed[k].append(lease.task.payload["i"])
            b.ack(lease.tag)

    threads = [threading.Thread(target=drain, args=(k,)) for k in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    got = sorted(i for part in claimed for i in part)
    assert got == list(range(n))  # nothing lost, nothing double-claimed
    # separate instances on one directory: rename races must have occurred
    assert sum(b.stats["stale_claims"] for b in brokers) > 0
    assert producer.idle()


# ---------------------------------------------------------------------------
# the bench itself cannot rot
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_ensemble_bench_smoke(tmp_path):
    from benchmarks import ensemble_throughput as ET
    from benchmarks.bench_schema import ENSEMBLE_SPEC, check_doc
    out = str(tmp_path / "BENCH_ensemble.json")
    r = ET.run(quick=True, out=out, workroot=str(tmp_path),
               n_tasks=6, max_bundle=8, sur_rows=32, sur_steps=25,
               load_bundles=5, xb_samples=48, xb_bundle=4,
               mesh_tasks=2, mesh_bundle=16)
    import json
    with open(out) as f:
        on_disk = json.load(f)
    assert on_disk["meta"]["bench"] == "ensemble_throughput"
    # the artifact the bench writes satisfies its documented schema
    assert check_doc(on_disk, ENSEMBLE_SPEC, "smoke") == []
    for scen in ("ragged", "uniform"):
        row = r[scen]
        assert row["baseline"]["samples"] == row["fused"]["samples"]
        assert row["speedup"] > 0
        assert row["fused"]["traces"] <= row["bucket_bound"]
    xb = r["engine_xbatch"]
    assert xb["per_worker"]["samples_per_s"] > 0
    assert xb["xbatch"]["samples_per_s"] > 0
    assert xb["xbatch"]["engine"]["batches"] >= 1
    md = r["mesh_dispatch"]
    if "skipped" not in md:  # subprocess ran: equivalence must hold
        assert md["bit_equal"] is True
        assert md["jag_max_rel_diff"] <= 1e-3
    assert r["surrogate"]["prediction_max_abs_diff"] < 1e-2
    assert r["loads"]["warm_load_s"] <= r["loads"]["cold_load_s"]
