"""Deterministic stand-in for `hypothesis` when it is not installed.

The tier-1 suite property-tests the hierarchy/bundler/kernel invariants
with hypothesis, but the runtime image does not ship it and we cannot pip
install.  This shim implements the tiny strategy subset those tests use
(integers / floats / lists / sets, ``@given``, ``@settings``) with a
seeded PRNG, so the properties still execute over a few dozen random
examples instead of being skipped.  When the real hypothesis is available
(see requirements-dev.txt) conftest.py leaves it alone.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_MAX_EXAMPLES_CAP = 60  # keep suite runtime bounded


class _Strategy:
    def __init__(self, gen):
        self._gen = gen

    def example(self, rnd: random.Random):
        return self._gen(rnd)


def integers(min_value: int = 0, max_value: int = 100) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda r: bool(r.getrandbits(1)))


def sampled_from(options) -> _Strategy:
    options = list(options)
    return _Strategy(lambda r: r.choice(options))


def _size(r: random.Random, min_size: int, max_size) -> int:
    hi = max_size if max_size is not None else min_size + 20
    return r.randint(min_size, max(hi, min_size))


def lists(elements: _Strategy, min_size: int = 0, max_size=None) -> _Strategy:
    return _Strategy(lambda r: [elements.example(r)
                                for _ in range(_size(r, min_size, max_size))])


def sets(elements: _Strategy, min_size: int = 0, max_size=None) -> _Strategy:
    # sets may come out smaller than the drawn size on duplicate elements —
    # matches hypothesis' "best effort" semantics closely enough for tests
    # that only require "some subset of the domain"
    def gen(r):
        out = {elements.example(r) for _ in range(_size(r, min_size, max_size))}
        while len(out) < min_size:
            out.add(elements.example(r))
        return out
    return _Strategy(gen)


def settings(**kwargs):
    def deco(fn):
        fn._hyp_settings = dict(kwargs)
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    """Run the test over N seeded random examples.

    Like hypothesis, positional strategies fill the test's *rightmost*
    parameters (anything to their left — pytest fixtures — passes through),
    and keyword strategies fill by name.  The wrapper hides the filled
    parameters from pytest via ``__signature__`` so fixture resolution
    still works.
    """
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        filled = set(kw_strategies)
        pos_filled: list = []
        if arg_strategies:
            pos = [p.name for p in params if p.name not in filled]
            pos_filled = pos[len(pos) - len(arg_strategies):]
            filled.update(pos_filled)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_hyp_settings", {})
            n = min(int(cfg.get("max_examples", 20)), _MAX_EXAMPLES_CAP)
            rnd = random.Random(0)
            for _ in range(n):
                # generated values pass by NAME so fixtures pytest supplies
                # (positionally or by keyword) can never collide with them
                gen = {k: s.example(rnd)
                       for k, s in zip(pos_filled, arg_strategies)}
                gen.update((k, s.example(rnd))
                           for k, s in kw_strategies.items())
                fn(*args, **kwargs, **gen)

        wrapper.__signature__ = sig.replace(
            parameters=[p for p in params if p.name not in filled])
        return wrapper
    return deco


def install() -> None:
    """Register this module as `hypothesis` + `hypothesis.strategies`."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.__shim__ = True
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from",
                 "lists", "sets"):
        setattr(st, name, globals()[name])
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
