"""Roofline assembly: read the dry-run JSONs (launch/dryrun.py --all) and
derive the three-term roofline per (arch x shape x mesh).

Hardware model (per instructions): TPU v5e — 197 TFLOP/s bf16/chip,
819 GB/s HBM/chip, ~50 GB/s/link ICI with 4 links/chip.

  compute_s    = HLO_FLOPs(per chip) / 197e12
  memory_s     = HLO_bytes(per chip) / 819e9
  collective_s = collective_bytes(per chip) / (4 * 50e9)

Both the scan-true numbers and the probe-reconstructed numbers (see
launch/dryrun.py for why reconstruction is needed) are available; the table
uses the reconstructed ones.  ``useful_s`` = MODEL_FLOPS/(chips*peak) and
``roofline_fraction`` = useful_s / max(term) — the score in §Perf.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9
ICI_LINKS = 4


def analytic_traffic_bytes(res: Dict) -> Optional[float]:
    """Achievable per-chip HBM traffic (bytes) for the cell — the yardstick
    the compiled `bytes accessed` is judged against (CPU-backend HLO byte
    counts are fusion-pessimistic; a fused TPU program approaches this).

    Model: bf16 weights are read fwd+bwd per microbatch; fp32 master/m/v
    optimizer state read+written once; remat="full" stores one activation
    per layer per token; logits materialize once fwd+bwd.  Decode reads all
    weights + the KV/state cache once per token.
    """
    try:
        import sys
        sys.path.insert(0, "src")
        from repro.configs import registry
        from repro.configs.base import SHAPES
        cfg = registry.get_config(res["arch"])
    except Exception:
        return None
    shape = SHAPES[res["shape"]]
    chips = res["chips"]
    P = res["n_params"]
    Pa = res["n_active_params"]
    tokens = shape.global_batch * shape.seq_len
    act = cfg.n_layers * tokens * cfg.d_model * 2 * 3  # save w + 2 reads
    if shape.kind == "train":
        nmb = max(1, cfg.microbatch)
        weights = 2 * 2 * P * nmb if not cfg.n_experts else \
            2 * 2 * (P + (nmb - 1) * Pa)  # EP shards re-read active experts
        opt = 32 * P  # fp32 master/m/v r+w, grads r+w
        logits = 2 * tokens * cfg.vocab_size * 2 * 2
        total = weights + opt + act + logits
    elif shape.kind == "prefill":
        total = 2 * P + act + tokens * cfg.d_model * 2 * 4  # + cache write
    else:  # decode: weights + full cache read per token
        cache = _cache_bytes(cfg, shape)
        total = 2 * Pa + cache + shape.global_batch * cfg.vocab_size * 2
    return total / chips


def _cache_bytes(cfg, shape) -> float:
    B, S = shape.global_batch, shape.seq_len
    total = 0.0
    for spec in cfg.plan:
        if spec.kind in ("attn", "shared_attn", "dec"):
            L = min(spec.sliding_window or cfg.decode_window or S, S)
            total += 2 * B * L * cfg.n_kv_heads * cfg.head_dim * 2
        elif spec.kind == "mla":
            total += B * S * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
        elif spec.kind == "mamba2":
            d_inner = cfg.ssm_expand * cfg.d_model
            total += B * d_inner * cfg.ssm_state * 4
        elif spec.kind == "rwkv6":
            H = cfg.d_model // cfg.rwkv_head_dim
            total += B * H * cfg.rwkv_head_dim ** 2 * 4
        elif spec.kind == "xattn":
            T = cfg.n_img_tokens or cfg.enc_len
            total += 2 * B * T * cfg.n_kv_heads * cfg.head_dim * 2
    return total


def derive_row(res: Dict) -> Optional[Dict]:
    if not res.get("ok"):
        return None
    rec = res.get("reconstructed", res)
    chips = res["chips"]
    coll = rec["collectives"]
    coll_bytes = sum(v for k, v in coll.items() if k != "count")
    compute_s = rec["flops"] / PEAK_FLOPS
    memory_s = rec["bytes_accessed"] / HBM_BW
    collective_s = coll_bytes / (ICI_LINKS * ICI_BW_PER_LINK)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful_s = res["model_flops"] / chips / PEAK_FLOPS
    bound = max(terms.values())
    # achievable lower bound for this workload on this hardware: max of
    # useful-compute time and analytic min HBM-traffic time
    traffic = analytic_traffic_bytes(res)
    achievable_s = max(useful_s, (traffic or 0.0) / HBM_BW)
    return {
        "arch": res["arch"], "shape": res["shape"], "mesh": res["mesh"],
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops": res["model_flops"],
        "hlo_flops_chip": rec["flops"],
        "useful_ratio": res["model_flops"] / chips / max(rec["flops"], 1e-9),
        "useful_s": useful_s,
        "achievable_s": achievable_s,
        "roofline_fraction": achievable_s / bound if bound > 0 else 0.0,
        "temp_gb": res.get("memory", {}).get("temp_bytes", 0) / 2 ** 30,
    }


def load_rows(paths: List[str]) -> List[Dict]:
    rows = []
    for p in paths:
        if not os.path.exists(p):
            continue
        for res in json.load(open(p)):
            row = derive_row(res)
            if row:
                rows.append(row)
    return rows


def format_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':<22}{'shape':<12}{'mesh':<9}{'compute_s':>10}"
           f"{'memory_s':>10}{'coll_s':>9}{'dom':>6}{'useful':>8}"
           f"{'achiev_s':>10}{'roof%':>7}{'temp_GB':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(
            f"{r['arch']:<22}{r['shape']:<12}{r['mesh']:<9}"
            f"{r['compute_s']:>10.4f}{r['memory_s']:>10.4f}"
            f"{r['collective_s']:>9.4f}{r['dominant'][:4]:>6}"
            f"{r['useful_ratio']:>8.2f}{r['achievable_s']:>10.4f}"
            f"{100*r['roofline_fraction']:>6.1f}%"
            f"{r['temp_gb']:>8.2f}")
    return "\n".join(lines)


def main():
    import glob
    paths = (sorted(glob.glob("results/dryrun_single_pod_final.json")) or
             ["results/dryrun_single_pod.json"])
    paths += ["results/dryrun_multi_pod.json"]
    rows = load_rows(paths)
    if not rows:
        print("no dry-run results found; run "
              "`python -m repro.launch.dryrun --all --out "
              "results/dryrun_single_pod.json` first")
        return
    print(format_table(rows))
    with open("results/roofline.csv", "w") as f:
        keys = list(rows[0].keys())
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(str(r[k]) for k in keys) + "\n")
    print("\nwrote results/roofline.csv")


if __name__ == "__main__":
    main()
