"""Ensemble hot-path throughput benchmark (the post-broker bottleneck).

Three measurements, each comparing the fused hot path against the seed
("baseline") behavior re-created faithfully inside this process:

* **ragged** — the optimization-loop scenario: a stream of ragged-size
  bundles (the sizes an active-learning loop actually produces).  Baseline
  constructs a fresh ``EnsembleExecutor`` per task with a private,
  exact-size jit cache (the seed's ``OptimizationLoop._sim_step``); fused
  uses one process-wide executor with power-of-two bucket padding.
* **uniform** — the same comparison on fixed-size bundles, isolating the
  executor-construction / cache-reuse win from the bucketing win.
* **surrogate** — deep-ensemble training wall-clock: the seed's eager
  per-member Python loop (jit re-closed per member => recompile per member,
  ``steps`` dispatches each) vs the single jitted ``lax.scan`` over steps
  vmapped over members.

Recompile counts come from ``repro.core.ensemble.trace_count()`` (a counter
incremented inside the traced function, i.e. once per XLA compile).

Writes ``BENCH_ensemble.json`` at the repo root — schema documented in
benchmarks/README.md.

Usage: PYTHONPATH=src python -m benchmarks.ensemble_throughput [--quick]
       [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from typing import Dict, List

import numpy as np

DEFAULT_OUT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_ensemble.json"))


# ---------------------------------------------------------------------------
# ragged / uniform bundle execution
# ---------------------------------------------------------------------------

def ragged_sizes(n_tasks: int, max_bundle: int, seed: int = 0) -> List[int]:
    """A plausible optimization-loop size stream: mostly full bundles with a
    ragged tail per iteration, plus odd resubmission fragments."""
    rng = np.random.default_rng(seed)
    sizes = []
    while len(sizes) < n_tasks:
        full, tail = divmod(int(rng.integers(1, 4) * max_bundle + rng.integers(0, max_bundle)),
                            max_bundle)
        sizes.extend([max_bundle] * full)
        if tail:
            sizes.append(tail)
        if rng.random() < 0.3:  # a crawl-and-resubmit fragment
            sizes.append(int(rng.integers(1, max(2, max_bundle // 2))))
    return sizes[:n_tasks]


def _run_stream(sizes: List[int], fused: bool, workdir: str) -> Dict:
    """Execute one bundle per size; returns wall-clock + trace accounting."""
    import jax  # noqa: F401  (imported late so --help stays fast)
    from repro.core import ensemble as E
    from repro.core.bundler import Bundler
    from repro.sim import jag_simulate

    # a per-stream wrapper gives each scenario its own compile-cache key,
    # so every measurement pays its own compiles (no cross-scenario warmth)
    def simulator(u, rng):
        return jag_simulate(u, rng)

    rng = np.random.default_rng(1)
    blocks = [rng.random((s, 5)).astype(np.float32) for s in sizes]
    bundler = Bundler(workdir)
    t_traces = E.trace_count()
    shared = E.EnsembleExecutor(simulator, bundler) if fused else None
    lo = 0
    t0 = time.perf_counter()
    for block in blocks:
        hi = lo + len(block)
        if fused:
            ex = shared
        else:
            # the seed hot path: fresh executor per task, private cache,
            # exact-size compile (bucketing off)
            ex = E.EnsembleExecutor(simulator, bundler, bucketed=False,
                                    share_cache=False)
        ex.run_bundle(lo, hi, block)
        lo = hi
    wall = time.perf_counter() - t0
    n = sum(sizes)
    return {"tasks": len(sizes), "samples": n, "wall_s": wall,
            "samples_per_s": n / wall,
            "traces": E.trace_count() - t_traces}


def bench_bundles(n_tasks: int, max_bundle: int, workroot: str) -> Dict:
    import tempfile
    out: Dict = {}
    for name, sizes in (
            ("ragged", ragged_sizes(n_tasks, max_bundle)),
            ("uniform", [max_bundle] * n_tasks)):
        row: Dict = {"max_bundle": max_bundle}
        for mode in ("baseline", "fused"):
            with tempfile.TemporaryDirectory(dir=workroot) as d:
                row[mode] = _run_stream(sizes, mode == "fused", d)
        row["speedup"] = (row["fused"]["samples_per_s"]
                         / row["baseline"]["samples_per_s"])
        # the bucket schedule bounds fused compiles: one per power-of-two
        # bucket <= max bundle size in the stream
        row["bucket_bound"] = int(math.ceil(math.log2(max(sizes)))) + 1
        out[name] = row
    return out


# ---------------------------------------------------------------------------
# surrogate training
# ---------------------------------------------------------------------------

def _train_reference(X, y, n_members=3, hidden=64, steps=300, lr=3e-3, seed=0):
    """The seed's eager per-member loop, verbatim (kept here as the
    baseline; core/active.py now trains with one scanned compile)."""
    import jax
    import jax.numpy as jnp
    from repro.core.active import Surrogate, _mlp_apply, _mlp_init

    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32)

    def loss_fn(p):
        return jnp.mean((_mlp_apply(p, X) - y) ** 2)

    members = []
    for m in range(n_members):
        rng = jax.random.PRNGKey(seed * 131 + m)
        p = _mlp_init(rng, [X.shape[1], hidden, hidden, 1])
        mom = jax.tree.map(jnp.zeros_like, p)
        vel = jax.tree.map(jnp.zeros_like, p)

        @jax.jit
        def step(p, mom, vel, i):
            g = jax.grad(loss_fn)(p)
            mom = jax.tree.map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, mom, g)
            vel = jax.tree.map(lambda v_, g_: 0.999 * v_ + 0.001 * g_ ** 2,
                               vel, g)
            p = jax.tree.map(
                lambda p_, m_, v_: p_ - lr * m_ / (jnp.sqrt(v_) + 1e-8),
                p, mom, vel)
            return p, mom, vel

        for i in range(steps):
            p, mom, vel = step(p, mom, vel, i)
        members.append(p)
    return Surrogate(members)


def bench_surrogate(n_rows: int, steps: int, repeats: int = 3) -> Dict:
    """Per-call training wall-clock at the optimization loop's archive size.

    The loop trains two surrogates per iteration, every iteration, on an
    archive of batch_per_iter × iters rows (~50–200).  The seed loop
    re-closes and re-jits its step per member on EVERY call, so each call
    pays n_members compiles plus steps × members eager dispatches — that
    recurring cost is the baseline (min over calls; every call recompiles
    by construction).  The scanned trainer compiles once per row-bucket per
    process (reported as ``scanned_cold_s``) and every subsequent call runs
    warm (``scanned_s`` = min over warm calls) — the steady-state cost the
    loop actually pays from its second training call onward."""
    from repro.core.active import train_surrogate

    rng = np.random.default_rng(0)
    X = rng.random((n_rows, 5)).astype(np.float32)
    y = (np.sin(3 * X[:, 0]) + X[:, 1] ** 2).astype(np.float32)
    y = (y - y.min()) / (y.max() - y.min())

    def timed(fn, seed):
        t0 = time.perf_counter()
        sur = fn(seed)
        sur.predict(X[:8])  # force any pending device work
        return time.perf_counter() - t0, sur

    base_calls, scan_calls = [], []
    sur_b = sur_s = None
    for r in range(repeats):
        dt, sur_b = timed(lambda s: _train_reference(X, y, steps=steps,
                                                     seed=s), 0)
        base_calls.append(dt)
        dt, sur_s = timed(lambda s: train_surrogate(X, y, steps=steps,
                                                    seed=s), 0)
        scan_calls.append(dt)
    mu_b, _ = sur_b.predict(X)
    mu_s, _ = sur_s.predict(X)
    base_s = min(base_calls)
    scan_s = min(scan_calls[1:]) if len(scan_calls) > 1 else scan_calls[0]
    return {"rows": n_rows, "steps": steps,
            "baseline_s": base_s, "scanned_s": scan_s,
            "scanned_cold_s": scan_calls[0],
            "speedup": base_s / scan_s,
            "prediction_max_abs_diff": float(np.max(np.abs(mu_b - mu_s)))}


# ---------------------------------------------------------------------------
# incremental archive loads
# ---------------------------------------------------------------------------

def bench_loads(n_bundles: int, bundle: int, workroot: str) -> Dict:
    """Cost of the analyze-funnel read: full re-read vs cached/incremental."""
    import tempfile
    from repro.core.bundler import Bundler
    rng = np.random.default_rng(2)
    with tempfile.TemporaryDirectory(dir=workroot) as d:
        b = Bundler(d)
        for i in range(n_bundles):
            lo = i * bundle
            b.write_bundle(lo, lo + bundle, {
                "inputs": rng.random((bundle, 5)).astype(np.float32),
                "yield": rng.random(bundle).astype(np.float32)})
        cold = Bundler(d)
        t0 = time.perf_counter()
        cold.load_all()
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        cold.load_all()  # warm: unchanged tree, served from cache
        warm_s = time.perf_counter() - t0
        # incremental: one new bundle lands, only it is decompressed
        lo = n_bundles * bundle
        b.write_bundle(lo, lo + bundle, {
            "inputs": rng.random((bundle, 5)).astype(np.float32),
            "yield": rng.random(bundle).astype(np.float32)})
        t0 = time.perf_counter()
        cold.load_all()
        incr_s = time.perf_counter() - t0
    return {"bundles": n_bundles, "bundle": bundle,
            "cold_load_s": cold_s, "warm_load_s": warm_s,
            "incremental_load_s": incr_s,
            "warm_speedup": cold_s / max(warm_s, 1e-9)}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run(quick: bool = False, out: str = DEFAULT_OUT, workroot: str = None,
        n_tasks: int = None, max_bundle: int = None, sur_rows: int = None,
        sur_steps: int = None, load_bundles: int = None) -> Dict:
    """Explicit size kwargs override the quick/full presets (the slow-marked
    smoke test runs everything tiny so the bench itself cannot rot)."""
    import tempfile
    import jax

    workroot = workroot or tempfile.gettempdir()
    n_tasks = n_tasks or (24 if quick else 96)
    max_bundle = max_bundle or (16 if quick else 48)
    results = {
        "meta": {
            "bench": "ensemble_throughput",
            "quick": quick,
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "unix_time": time.time(),
        },
        **bench_bundles(n_tasks, max_bundle, workroot),
        # 128 rows ≈ the loop's archive after 2–3 iterations of batch 48
        "surrogate": bench_surrogate(n_rows=sur_rows or (64 if quick else 128),
                                     steps=sur_steps or (100 if quick else 300),
                                     repeats=2 if quick else 3),
        "loads": bench_loads(n_bundles=load_bundles or (20 if quick else 100),
                             bundle=16, workroot=workroot),
    }
    if out:
        tmp = out + ".tmp"
        with open(tmp, "w") as f:
            json.dump(results, f, indent=2)
        os.rename(tmp, out)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="where to write BENCH_ensemble.json ('' to skip)")
    args = ap.parse_args()
    r = run(quick=args.quick, out=args.out or None)
    for scen in ("ragged", "uniform"):
        row = r[scen]
        print(f"{scen}: {row['baseline']['samples_per_s']:.0f} -> "
              f"{row['fused']['samples_per_s']:.0f} samples/s "
              f"({row['speedup']:.1f}x); compiles "
              f"{row['baseline']['traces']} -> {row['fused']['traces']} "
              f"(bound {row['bucket_bound']})")
    s = r["surrogate"]
    print(f"surrogate: {s['baseline_s']:.2f}s -> {s['scanned_s']:.2f}s "
          f"({s['speedup']:.1f}x), max |Δmu|={s['prediction_max_abs_diff']:.2e}")
    ld = r["loads"]
    print(f"loads: cold {ld['cold_load_s']*1e3:.1f}ms, warm "
          f"{ld['warm_load_s']*1e3:.2f}ms, +1 bundle "
          f"{ld['incremental_load_s']*1e3:.2f}ms")
    if args.out:
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
