"""Ensemble hot-path throughput benchmark (the post-broker bottleneck).

Five measurements, each comparing the fused hot path against the seed
("baseline") behavior re-created faithfully inside this process:

* **ragged** — the optimization-loop scenario: a stream of ragged-size
  bundles (the sizes an active-learning loop actually produces).  Baseline
  constructs a fresh ``EnsembleExecutor`` per task with a private,
  exact-size jit cache (the seed's ``OptimizationLoop._sim_step``); fused
  uses one process-wide executor with power-of-two bucket padding.
* **uniform** — the same comparison on fixed-size bundles, isolating the
  executor-construction / cache-reuse win from the bucketing win.
* **surrogate** — deep-ensemble training wall-clock: the seed's eager
  per-member Python loop (jit re-closed per member => recompile per member,
  ``steps`` dispatches each) vs the single jitted ``lax.scan`` over steps
  vmapped over members.
* **engine_xbatch** — cross-worker coalescing: the same ragged leaf-task
  stream drained by 4 lease-pump workers at batch 4, once with per-worker
  coalescing only (``engine=None``, the pre-engine path: each worker can
  fuse at most its OWN 4-lease window, and the four threads execute
  concurrently — convoying on the GIL for the host-side work each launch
  drags along: padding, device transfer, result conversion, bundle
  writes) and once through the shared micro-batching ExecutionEngine
  (tasks from all four workers accumulate into one buffer and flush as
  4x-wider fused launches in ONE executing thread, with the workers
  reduced to cheap event waiters).  Acceptance: >= 2x samples/s.
* **mesh_dispatch** — multi-device shard_map dispatch, run in a
  subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
  (the in-process bench keeps the 1-device default): fused bundles on one
  device vs shard_mapped over the 8-device mesh, with the equivalence
  fields the acceptance test relies on (strict bit-for-bit for an
  IEEE-exact simulator; <= 1e-3 max relative diff for the
  transcendental-heavy JAG stand-in: vectorized pow/exp codegen may
  legally differ in the last ULP across per-shard batch widths, and the
  ~v^5.8 power laws amplify that into ~1e-4 relative) and the
  compile-count bound.  On a CPU host the 8 "devices" share the same
  cores, so throughput parity — not speedup — is expected; the scenario
  exists to prove correctness + compile accounting of the dispatch path
  that pays off on real multi-device hosts.

Recompile counts come from ``repro.core.ensemble.trace_count()`` (a counter
incremented inside the traced function, i.e. once per XLA compile).

Writes ``BENCH_ensemble.json`` at the repo root — schema documented in
benchmarks/README.md.

Usage: PYTHONPATH=src python -m benchmarks.ensemble_throughput [--quick]
       [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from typing import Dict, List

import numpy as np

DEFAULT_OUT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "BENCH_ensemble.json"))


# ---------------------------------------------------------------------------
# ragged / uniform bundle execution
# ---------------------------------------------------------------------------

def ragged_sizes(n_tasks: int, max_bundle: int, seed: int = 0) -> List[int]:
    """A plausible optimization-loop size stream: mostly full bundles with a
    ragged tail per iteration, plus odd resubmission fragments."""
    rng = np.random.default_rng(seed)
    sizes = []
    while len(sizes) < n_tasks:
        full, tail = divmod(int(rng.integers(1, 4) * max_bundle + rng.integers(0, max_bundle)),
                            max_bundle)
        sizes.extend([max_bundle] * full)
        if tail:
            sizes.append(tail)
        if rng.random() < 0.3:  # a crawl-and-resubmit fragment
            sizes.append(int(rng.integers(1, max(2, max_bundle // 2))))
    return sizes[:n_tasks]


def _run_stream(sizes: List[int], fused: bool, workdir: str) -> Dict:
    """Execute one bundle per size; returns wall-clock + trace accounting."""
    import jax  # noqa: F401  (imported late so --help stays fast)
    from repro.core import ensemble as E
    from repro.core.bundler import Bundler
    from repro.sim import jag_simulate

    # a per-stream wrapper gives each scenario its own compile-cache key,
    # so every measurement pays its own compiles (no cross-scenario warmth)
    def simulator(u, rng):
        return jag_simulate(u, rng)

    rng = np.random.default_rng(1)
    blocks = [rng.random((s, 5)).astype(np.float32) for s in sizes]
    bundler = Bundler(workdir)
    t_traces = E.trace_count()
    shared = E.EnsembleExecutor(simulator, bundler) if fused else None
    lo = 0
    t0 = time.perf_counter()
    for block in blocks:
        hi = lo + len(block)
        if fused:
            ex = shared
        else:
            # the seed hot path: fresh executor per task, private cache,
            # exact-size compile (bucketing off)
            ex = E.EnsembleExecutor(simulator, bundler, bucketed=False,
                                    share_cache=False)
        ex.run_bundle(lo, hi, block)
        lo = hi
    wall = time.perf_counter() - t0
    n = sum(sizes)
    return {"tasks": len(sizes), "samples": n, "wall_s": wall,
            "samples_per_s": n / wall,
            "traces": E.trace_count() - t_traces}


def bench_bundles(n_tasks: int, max_bundle: int, workroot: str) -> Dict:
    import tempfile
    out: Dict = {}
    for name, sizes in (
            ("ragged", ragged_sizes(n_tasks, max_bundle)),
            ("uniform", [max_bundle] * n_tasks)):
        row: Dict = {"max_bundle": max_bundle}
        for mode in ("baseline", "fused"):
            with tempfile.TemporaryDirectory(dir=workroot) as d:
                row[mode] = _run_stream(sizes, mode == "fused", d)
        row["speedup"] = (row["fused"]["samples_per_s"]
                         / row["baseline"]["samples_per_s"])
        # the bucket schedule bounds fused compiles: one per power-of-two
        # bucket <= max bundle size in the stream
        row["bucket_bound"] = int(math.ceil(math.log2(max(sizes)))) + 1
        out[name] = row
    return out


# ---------------------------------------------------------------------------
# cross-worker micro-batching (ExecutionEngine)
# ---------------------------------------------------------------------------

def ragged_partition(n: int, k: int, seed: int = 0):
    """Partition [0, n) into exactly k contiguous ragged spans — the shape
    of a crawl-and-resubmit stream (the stage counter expects k bundles)."""
    rng = np.random.default_rng(seed)
    cuts = np.sort(rng.choice(np.arange(1, n), size=k - 1, replace=False))
    bounds = [0, *cuts.tolist(), n]
    return list(zip(bounds[:-1], bounds[1:]))


def _xbatch_run(simulator, spans, n_samples: int, bundle: int,
                workroot: str, use_engine: bool, workers: int = 4,
                batch: int = 4) -> Dict:
    """Drain one ragged leaf-task stream with a 4-worker pool; returns
    wall-clock + launch accounting.  Leaf tasks are enqueued directly
    (the resubmit path) so both modes see the IDENTICAL task stream."""
    import tempfile
    from repro.core import ensemble as E
    from repro.core.bundler import Bundler
    from repro.core.queue import PRIORITY_REAL, new_task
    from repro.core.runtime import MerlinRuntime
    from repro.core.spec import Step, StudySpec
    from repro.core.worker import WorkerPool

    with tempfile.TemporaryDirectory(dir=workroot) as ws:
        rt = MerlinRuntime(workspace=ws)
        bundler = Bundler(os.path.join(ws, "res"))
        ex = E.EnsembleExecutor(simulator, bundler)
        rt.register("sim", ex.step_fn())
        spec = StudySpec(name="xb", steps=[Step(name="sim", fn="sim")])
        study = "xb-bench"
        rng = np.random.default_rng(7)
        rt.register_study(spec, study_id=study,
                          samples=rng.random((n_samples, 5))
                          .astype(np.float32))
        tasks = [new_task("real",
                          {"study": study, "stage": 0, "combo": 0,
                           "n_samples": n_samples, "bundle": bundle,
                           "fanout": 16, "samples": [lo, hi],
                           "real_queue": "real", "gen_queue": "gen"},
                          priority=PRIORITY_REAL, queue="real")
                 for lo, hi in spans]
        rt.broker.put_many(tasks)
        engine_cfg = {"max_batch": workers * batch, "max_wait_ms": 25.0}
        t0 = time.perf_counter()
        with WorkerPool(rt, n_workers=workers, batch=batch,
                        engine="auto" if use_engine else None,
                        engine_cfg=engine_cfg) as pool:
            done = pool.drain(timeout=600)
            wall = time.perf_counter() - t0
            stats = pool.stats()
        assert done, "xbatch scenario failed to drain"
        out = {"wall_s": wall, "samples_per_s": n_samples / wall,
               "launches": ex.stats["launches"],
               "device_util": ex.stats["samples"] /
               max(ex.stats["samples"] + ex.stats["padded_samples"], 1)}
        if "engine" in stats:
            eng = stats["engine"]
            out["engine"] = {k: eng[k] for k in
                            ("batches", "avg_batch", "max_batch_seen",
                             "size_flushes", "deadline_flushes",
                             "forced_flushes", "utilization")}
        return out


def bench_engine_xbatch(n_samples: int, bundle: int, workroot: str,
                        repeats: int = 3) -> Dict:
    """Per-worker coalescing vs the shared engine on one ragged stream.

    Best of ``repeats`` interleaved runs per mode, after an untimed
    warmup run of each (first-run effects — thread-pool spin-up, cold
    page cache on the workspace tmpfs, CPU governor ramp — hit whichever
    mode goes first by ~2x on small hosts)."""
    from repro.core import ensemble as E
    from repro.sim import jag_simulate

    def simulator(u, rng):  # scenario-private compile-cache key
        return jag_simulate(u, rng)

    k = n_samples // bundle
    spans = ragged_partition(n_samples, k)
    # warm every bucket a fused run could hit (both modes share the cache,
    # so neither timed run pays compiles — we measure dispatch, not XLA)
    warm = E.EnsembleExecutor(simulator)
    rng = np.random.default_rng(3)
    for b in E.bucket_schedule(E.bucket_for(n_samples)):
        warm.run_bundle(0, b, rng.random((b, 5)).astype(np.float32))
    warm_spans = ragged_partition(n_samples // 4, max(2, k // 4))
    modes: Dict[str, Dict] = {}
    for r in range(-1, repeats):  # interleaved: box-load drift hits both
        for name, use_engine in (("per_worker", False), ("xbatch", True)):
            if r < 0:  # warmup lap: run small, discard
                _xbatch_run(simulator, warm_spans, n_samples // 4, bundle,
                            workroot, use_engine)
                continue
            res = _xbatch_run(simulator, spans, n_samples, bundle,
                              workroot, use_engine)
            best = modes.get(name)
            if best is None or res["samples_per_s"] > best["samples_per_s"]:
                modes[name] = res
    return {"n_samples": n_samples, "tasks": k, "bundle": bundle,
            "workers": 4, "batch": 4, **modes,
            "speedup": (modes["xbatch"]["samples_per_s"]
                        / modes["per_worker"]["samples_per_s"])}


# ---------------------------------------------------------------------------
# multi-device shard_map dispatch (subprocess: forces 8 host devices)
# ---------------------------------------------------------------------------

def _exact_sim_src():
    """An IEEE-exact simulator (add/mul/div/sqrt + counter-based uniform
    bits only): every op is correctly rounded per element, so any batch
    split produces bit-identical results — the strict half of the
    equivalence check."""
    import jax
    import jax.numpy as jnp

    def exact_sim(u, rng):
        s = u * 2.0 + 0.25
        noise = jax.random.uniform(rng, u.shape) * 0.001
        return {"v": s / (1.0 + u) + noise,
                "w": jnp.sqrt(s),
                "s": (u * u).sum()}
    return exact_sim


def mesh_worker_main(cfg: Dict) -> None:
    """Entrypoint for the forced-8-device subprocess (``--mesh-worker``)."""
    import jax
    from repro.core import ensemble as E
    from repro.sim import jag_simulate

    def jag(u, rng):
        return jag_simulate(u, rng)

    exact = _exact_sim_src()
    sizes = cfg["sizes"]
    blocks = [np.random.default_rng(5).random((s, 5)).astype(np.float32)
              for s in sizes]
    out: Dict = {"devices": jax.local_device_count(), "sizes": sizes,
                 "bucket_bound": int(math.ceil(
                     math.log2(max(sizes)))) + 1}

    def stream(ex, tag):
        t_traces = E.trace_count()
        results = []
        lo = 0
        t0 = time.perf_counter()
        for blk in blocks:
            results.append(ex.run_bundle(lo, lo + len(blk), blk))
            lo += len(blk)
        wall = time.perf_counter() - t0
        n = sum(sizes)
        out[tag] = {"wall_s": wall, "samples_per_s": n / wall,
                    "traces": E.trace_count() - t_traces,
                    "mesh_launches": ex.stats["mesh_launches"]}
        return results

    # strict bit-for-bit: IEEE-exact simulator
    r1 = stream(E.EnsembleExecutor(exact, mesh=None), "exact_single")
    r2 = stream(E.EnsembleExecutor(exact), "exact_sharded")
    out["bit_equal"] = all(
        np.array_equal(np.asarray(a[k]), np.asarray(b[k]), equal_nan=True)
        for a, b in zip(r1, r2) for k in a)
    # transcendental-heavy JAG: last-ULP codegen variance allowed
    j1 = stream(E.EnsembleExecutor(jag, mesh=None), "jag_single")
    j2 = stream(E.EnsembleExecutor(jag), "jag_sharded")
    rel = 0.0
    for a, b in zip(j1, j2):
        for k in a:
            x, y = np.asarray(a[k]), np.asarray(b[k])
            m = np.isfinite(x)
            d = np.abs(x - y)[m] / np.maximum(np.abs(x[m]), 1e-30)
            if d.size:
                rel = max(rel, float(d.max()))
    out["jag_max_rel_diff"] = rel
    print(json.dumps(out), flush=True)


def bench_mesh_dispatch(n_tasks: int, bundle: int,
                        devices: int = 8) -> Dict:
    """Run the mesh scenario in a subprocess with forced host devices."""
    import subprocess
    import sys

    import repro.core  # repro itself may be a namespace package (no file)
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(repro.core.__file__))))
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    # uniform bundles (shardable buckets) plus one ragged tail exercising
    # the small-bucket single-device fallback
    cfg = {"sizes": [bundle] * n_tasks + [max(2, bundle // 5)]}
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}"
                        ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [src, root] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                       if p])
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.ensemble_throughput",
         "--mesh-worker", json.dumps(cfg)],
        capture_output=True, text=True, env=env, cwd=root, timeout=600)
    if proc.returncode != 0:
        return {"skipped": f"mesh worker failed: {proc.stderr[-500:]}"}
    try:
        return json.loads(proc.stdout.strip().splitlines()[-1])
    except (json.JSONDecodeError, IndexError):
        return {"skipped": f"unparseable mesh worker output: "
                           f"{proc.stdout[-300:]}"}


# ---------------------------------------------------------------------------
# surrogate training
# ---------------------------------------------------------------------------

def _train_reference(X, y, n_members=3, hidden=64, steps=300, lr=3e-3, seed=0):
    """The seed's eager per-member loop, verbatim (kept here as the
    baseline; core/active.py now trains with one scanned compile)."""
    import jax
    import jax.numpy as jnp
    from repro.core.active import Surrogate, _mlp_apply, _mlp_init

    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32)

    def loss_fn(p):
        return jnp.mean((_mlp_apply(p, X) - y) ** 2)

    members = []
    for m in range(n_members):
        rng = jax.random.PRNGKey(seed * 131 + m)
        p = _mlp_init(rng, [X.shape[1], hidden, hidden, 1])
        mom = jax.tree.map(jnp.zeros_like, p)
        vel = jax.tree.map(jnp.zeros_like, p)

        @jax.jit
        def step(p, mom, vel, i):
            g = jax.grad(loss_fn)(p)
            mom = jax.tree.map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, mom, g)
            vel = jax.tree.map(lambda v_, g_: 0.999 * v_ + 0.001 * g_ ** 2,
                               vel, g)
            p = jax.tree.map(
                lambda p_, m_, v_: p_ - lr * m_ / (jnp.sqrt(v_) + 1e-8),
                p, mom, vel)
            return p, mom, vel

        for i in range(steps):
            p, mom, vel = step(p, mom, vel, i)
        members.append(p)
    return Surrogate(members)


def bench_surrogate(n_rows: int, steps: int, repeats: int = 3) -> Dict:
    """Per-call training wall-clock at the optimization loop's archive size.

    The loop trains two surrogates per iteration, every iteration, on an
    archive of batch_per_iter × iters rows (~50–200).  The seed loop
    re-closes and re-jits its step per member on EVERY call, so each call
    pays n_members compiles plus steps × members eager dispatches — that
    recurring cost is the baseline (min over calls; every call recompiles
    by construction).  The scanned trainer compiles once per row-bucket per
    process (reported as ``scanned_cold_s``) and every subsequent call runs
    warm (``scanned_s`` = min over warm calls) — the steady-state cost the
    loop actually pays from its second training call onward."""
    from repro.core.active import train_surrogate

    rng = np.random.default_rng(0)
    X = rng.random((n_rows, 5)).astype(np.float32)
    y = (np.sin(3 * X[:, 0]) + X[:, 1] ** 2).astype(np.float32)
    y = (y - y.min()) / (y.max() - y.min())

    def timed(fn, seed):
        t0 = time.perf_counter()
        sur = fn(seed)
        sur.predict(X[:8])  # force any pending device work
        return time.perf_counter() - t0, sur

    base_calls, scan_calls = [], []
    sur_b = sur_s = None
    for r in range(repeats):
        dt, sur_b = timed(lambda s: _train_reference(X, y, steps=steps,
                                                     seed=s), 0)
        base_calls.append(dt)
        dt, sur_s = timed(lambda s: train_surrogate(X, y, steps=steps,
                                                    seed=s), 0)
        scan_calls.append(dt)
    mu_b, _ = sur_b.predict(X)
    mu_s, _ = sur_s.predict(X)
    base_s = min(base_calls)
    scan_s = min(scan_calls[1:]) if len(scan_calls) > 1 else scan_calls[0]
    return {"rows": n_rows, "steps": steps,
            "baseline_s": base_s, "scanned_s": scan_s,
            "scanned_cold_s": scan_calls[0],
            "speedup": base_s / scan_s,
            "prediction_max_abs_diff": float(np.max(np.abs(mu_b - mu_s)))}


# ---------------------------------------------------------------------------
# incremental archive loads
# ---------------------------------------------------------------------------

def bench_loads(n_bundles: int, bundle: int, workroot: str) -> Dict:
    """Cost of the analyze-funnel read: full re-read vs cached/incremental."""
    import tempfile
    from repro.core.bundler import Bundler
    rng = np.random.default_rng(2)
    with tempfile.TemporaryDirectory(dir=workroot) as d:
        b = Bundler(d)
        for i in range(n_bundles):
            lo = i * bundle
            b.write_bundle(lo, lo + bundle, {
                "inputs": rng.random((bundle, 5)).astype(np.float32),
                "yield": rng.random(bundle).astype(np.float32)})
        cold = Bundler(d)
        t0 = time.perf_counter()
        cold.load_all()
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        cold.load_all()  # warm: unchanged tree, served from cache
        warm_s = time.perf_counter() - t0
        # incremental: one new bundle lands, only it is decompressed
        lo = n_bundles * bundle
        b.write_bundle(lo, lo + bundle, {
            "inputs": rng.random((bundle, 5)).astype(np.float32),
            "yield": rng.random(bundle).astype(np.float32)})
        t0 = time.perf_counter()
        cold.load_all()
        incr_s = time.perf_counter() - t0
    return {"bundles": n_bundles, "bundle": bundle,
            "cold_load_s": cold_s, "warm_load_s": warm_s,
            "incremental_load_s": incr_s,
            "warm_speedup": cold_s / max(warm_s, 1e-9)}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run(quick: bool = False, out: str = DEFAULT_OUT, workroot: str = None,
        n_tasks: int = None, max_bundle: int = None, sur_rows: int = None,
        sur_steps: int = None, load_bundles: int = None,
        xb_samples: int = None, xb_bundle: int = None,
        mesh_tasks: int = None, mesh_bundle: int = None,
        with_mesh: bool = True) -> Dict:
    """Explicit size kwargs override the quick/full presets (the slow-marked
    smoke test runs everything tiny so the bench itself cannot rot)."""
    import tempfile
    import jax

    workroot = workroot or tempfile.gettempdir()
    n_tasks = n_tasks or (24 if quick else 96)
    max_bundle = max_bundle or (16 if quick else 48)
    results = {
        "meta": {
            "bench": "ensemble_throughput",
            "quick": quick,
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "unix_time": time.time(),
        },
        **bench_bundles(n_tasks, max_bundle, workroot),
        "engine_xbatch": bench_engine_xbatch(
            n_samples=xb_samples or (192 if quick else 384),
            bundle=xb_bundle or 4, workroot=workroot),
        # 128 rows ≈ the loop's archive after 2–3 iterations of batch 48
        "surrogate": bench_surrogate(n_rows=sur_rows or (64 if quick else 128),
                                     steps=sur_steps or (100 if quick else 300),
                                     repeats=2 if quick else 3),
        "loads": bench_loads(n_bundles=load_bundles or (20 if quick else 100),
                             bundle=16, workroot=workroot),
    }
    if with_mesh:
        results["mesh_dispatch"] = bench_mesh_dispatch(
            n_tasks=mesh_tasks or (6 if quick else 16),
            bundle=mesh_bundle or 32)
    md = results.get("mesh_dispatch", {})
    mesh_ran = bool(md) and "skipped" not in md
    results["acceptance"] = {
        # PR 5 bar: the shared engine's cross-worker coalescing must at
        # least double samples/s over per-worker coalescing on the same
        # ragged workload with the same 4-worker/batch-4 fleet
        "engine_xbatch_speedup": results["engine_xbatch"]["speedup"],
        "pass_xbatch": results["engine_xbatch"]["speedup"] >= 2.0,
        # ... and shard_map dispatch must be exactly equivalent (IEEE-exact
        # sim bit-for-bit; JAG within last-ULP codegen variance) within
        # the bucketed compile bound.  None = scenario did not run.
        "mesh_bit_equal": bool(md.get("bit_equal", False)),
        "pass_mesh": bool(
            md.get("bit_equal", False)
            and md.get("jag_max_rel_diff", 1.0) <= 1e-3
            and md.get("exact_sharded", {}).get("traces", 1 << 30)
            <= md.get("bucket_bound", 0)) if mesh_ran else None,
    }
    results["acceptance"]["pass"] = bool(
        results["acceptance"]["pass_xbatch"]
        and results["acceptance"]["pass_mesh"] is not False)
    if out:
        tmp = out + ".tmp"
        with open(tmp, "w") as f:
            json.dump(results, f, indent=2)
        os.rename(tmp, out)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="where to write BENCH_ensemble.json ('' to skip)")
    ap.add_argument("--mesh-worker", default=None, metavar="JSON",
                    help=argparse.SUPPRESS)  # internal: forced-device child
    args = ap.parse_args()
    if args.mesh_worker is not None:
        mesh_worker_main(json.loads(args.mesh_worker))
        return
    r = run(quick=args.quick, out=args.out or None)
    for scen in ("ragged", "uniform"):
        row = r[scen]
        print(f"{scen}: {row['baseline']['samples_per_s']:.0f} -> "
              f"{row['fused']['samples_per_s']:.0f} samples/s "
              f"({row['speedup']:.1f}x); compiles "
              f"{row['baseline']['traces']} -> {row['fused']['traces']} "
              f"(bound {row['bucket_bound']})")
    xb = r["engine_xbatch"]
    print(f"engine_xbatch: {xb['per_worker']['samples_per_s']:.0f} -> "
          f"{xb['xbatch']['samples_per_s']:.0f} samples/s "
          f"({xb['speedup']:.2f}x, bar >= 2x); launches "
          f"{xb['per_worker']['launches']} -> {xb['xbatch']['launches']}")
    md = r.get("mesh_dispatch", {})
    if "skipped" in md:
        print(f"mesh_dispatch: skipped ({md['skipped']})")
    elif md:
        print(f"mesh_dispatch: {md['devices']} devices, bit_equal="
              f"{md['bit_equal']}, jag max rel diff "
              f"{md['jag_max_rel_diff']:.1e}, sharded traces "
              f"{md['exact_sharded']['traces']} + "
              f"{md['jag_sharded']['traces']} (bound {md['bucket_bound']} "
              f"each), {md['jag_sharded']['samples_per_s']:.0f} samples/s "
              f"vs {md['jag_single']['samples_per_s']:.0f} single")
    s = r["surrogate"]
    print(f"surrogate: {s['baseline_s']:.2f}s -> {s['scanned_s']:.2f}s "
          f"({s['speedup']:.1f}x), max |Δmu|={s['prediction_max_abs_diff']:.2e}")
    ld = r["loads"]
    print(f"loads: cold {ld['cold_load_s']*1e3:.1f}ms, warm "
          f"{ld['warm_load_s']*1e3:.2f}ms, +1 bundle "
          f"{ld['incremental_load_s']*1e3:.2f}ms")
    if args.out:
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
