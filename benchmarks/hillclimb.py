"""Perf-iteration driver (§Perf of EXPERIMENTS.md).

Runs one (arch x shape) dry-run variant in a fresh 512-device subprocess,
derives the roofline terms, and appends a labelled record to
results/perf_log.json — one call per hypothesis->change->measure cycle.

  PYTHONPATH=src python -m benchmarks.hillclimb --arch rwkv6-3b \
      --shape train_4k --label chunk64 --override '{"ssm_chunk": 64}'
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

from benchmarks.roofline import derive_row


def run_variant(arch: str, shape: str, label: str, override=None,
                cache_dtype=None, multi_pod=False, log="results/perf_log.json"):
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        out = f.name
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out]
    if override:
        cmd += ["--override", json.dumps(override)]
    if cache_dtype:
        cmd += ["--cache-dtype", cache_dtype]
    if multi_pod:
        cmd += ["--multi-pod"]
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=3000)
    if r.returncode != 0:
        rec = {"arch": arch, "shape": shape, "label": label,
               "override": override, "ok": False,
               "error": (r.stdout + r.stderr)[-1500:]}
    else:
        res = json.load(open(out))[0]
        row = derive_row(res) or {}
        rec = {"arch": arch, "shape": shape, "label": label,
               "override": override, "cache_dtype": cache_dtype,
               "ok": res.get("ok", False), **row}
    os.unlink(out)
    logs = json.load(open(log)) if os.path.exists(log) else []
    logs.append(rec)
    os.makedirs(os.path.dirname(log), exist_ok=True)
    with open(log, "w") as f:
        json.dump(logs, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--label", required=True)
    ap.add_argument("--override", default=None)
    ap.add_argument("--cache-dtype", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    rec = run_variant(args.arch, args.shape, args.label,
                      json.loads(args.override) if args.override else None,
                      args.cache_dtype, args.multi_pod)
    print(json.dumps(rec, indent=1, default=str))


if __name__ == "__main__":
    main()
