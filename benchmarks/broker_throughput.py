"""Broker claim-throughput benchmark: the perf baseline for the task-queue
hot path (paper Sec. 2.3 "server stability" / Figs. 3-6 analogues).

Measures end-to-end drain throughput (claim + ack) in tasks/s for the
local broker backends at 1, 4, and 16 concurrent workers with batch sizes
1 and 8, for the NetBroker (real TCP sockets against a BrokerServer
fronting an InMemoryBroker and a FileBroker) at batch 1/8/32
(interleaved median-of-3), for the bin1-vs-JSON wire codec A/B on
array-heavy payloads, for a 2-shard ShardedBroker federation (two
in-process BrokerServers, queues split across them by the override map),
for the same-host ``shm://`` shared-memory transport under the identical
4-process fleet, and for a reference re-implementation of the *seed*
FileBroker claim loop (full listdir + sort per claim) so every speedup
is measured, not asserted.  An end-to-end study wall-time delta (same
study drained under bin1 vs forced-JSON) lands in ``meta.study_wall``.

Writes the ``BENCH_broker.json`` artifact (schema: benchmarks/README.md).
Acceptance ratios: NetBroker batched (b>=8) vs the indexed FileBroker
single-worker baseline ("going over the wire with batching costs nothing
vs the shared-filesystem broker", PR 3, bar >= 1x), the 2-shard
federation at b=8 vs the single net_mem b=8 server ("sharding scales
past one broker process", PR 4, bar >= 1.3x), binary codec >= 3x JSON at
b32 on array payloads, and shm beating the TCP loopback fleet (> 1x).

Usage: PYTHONPATH=src python -m benchmarks.broker_throughput \
           [--tasks N] [--quick] [--out PATH]
Prints ``name,tasks_per_s,detail`` CSV rows then a human-readable block.
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import shutil
import tempfile
import threading
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro import env as repro_env
from repro.core.netbroker import BrokerServer, NetBroker
from repro.core.queue import FileBroker, InMemoryBroker, Task, new_task
from repro.core.shardbroker import ShardedBroker

# artifact lands at the repo root regardless of the caller's CWD (matching
# ensemble_throughput) so run.py --quick refreshes the committed file
DEFAULT_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "..", "BENCH_broker.json")


# ---------------------------------------------------------------------------
# seed-era FileBroker claim loop (reference baseline)
# ---------------------------------------------------------------------------

class SeedFileBroker:
    """The pre-index FileBroker hot path: re-list + re-sort the queue
    directory on every single claim.  Kept here (benchmark-only) as the
    baseline the cached-index implementation is compared against."""

    def __init__(self, root: str):
        self.qdir = os.path.join(root, "queue")
        self.cdir = os.path.join(root, "claimed")
        os.makedirs(self.qdir, exist_ok=True)
        os.makedirs(self.cdir, exist_ok=True)
        self._seq = 0

    def put(self, task: Task) -> None:
        self._seq += 1
        name = f"{task.priority}-{self._seq:012d}-{task.id}.json"
        tmp = os.path.join(self.qdir, f".tmp-{name}")
        with open(tmp, "w") as f:
            f.write(task.to_json())
        os.rename(tmp, os.path.join(self.qdir, name))

    def put_many(self, tasks: List[Task]) -> None:
        for t in tasks:
            self.put(t)

    def get_many(self, n: int, timeout: float = 0.0, queues=None) -> list:
        out = []
        names = sorted(x for x in os.listdir(self.qdir)
                       if not x.startswith("."))  # O(n log n) EVERY claim
        for name in names[:n]:
            src = os.path.join(self.qdir, name)
            dst = os.path.join(self.cdir, f"{time.time():.3f}__{name}")
            try:
                os.rename(src, dst)
            except OSError:
                continue
            with open(dst) as f:
                out.append((Task.from_json(f.read()), dst))
        return [type("L", (), {"task": t, "tag": g})() for t, g in out]

    def ack_many(self, tags) -> None:
        for tag in tags:
            try:
                os.unlink(tag)
            except OSError:
                pass


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------

def drain(broker, n_tasks: int, n_workers: int, batch: int) -> float:
    """Drain ``n_tasks`` pre-queued tasks with ``n_workers`` threads;
    returns wall seconds from start to the LAST ack (tail-end empty polls
    don't pollute the measurement)."""
    lock = threading.Lock()
    state = {"done": 0, "t_last": 0.0}
    stop = threading.Event()
    t0 = time.perf_counter()

    def work():
        while not stop.is_set():
            leases = broker.get_many(batch, timeout=0.05)
            if not leases:
                continue  # others may still be in flight; stop flag decides
            broker.ack_many([l.tag for l in leases])
            with lock:
                state["done"] += len(leases)
                state["t_last"] = time.perf_counter()
                if state["done"] >= n_tasks:
                    stop.set()

    threads = [threading.Thread(target=work) for _ in range(n_workers)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    return state["t_last"] - t0


def bench(make_broker: Callable[[], object], n_tasks: int, n_workers: int,
          batch: int) -> dict:
    broker = make_broker()
    broker.put_many([new_task("real", {"i": i}, queue="bench")
                     for i in range(n_tasks)])
    wall = drain(broker, n_tasks, n_workers, batch)
    return {"tasks_per_s": n_tasks / wall, "wall_s": wall}


def bench_net(make_backend: Callable[[], object], n_tasks: int,
              n_workers: int, batch: int,
              codecs: Optional[Sequence[str]] = None,
              payload: Optional[Callable[[int], dict]] = None) -> dict:
    """Drain through real TCP sockets: BrokerServer + NetBroker client.

    ``codecs`` restricts the server's advertised wire codecs (so
    ``("json",)`` forces a JSON-negotiated connection — the rolling-
    upgrade fallback — for codec A/B scenarios); ``payload`` builds the
    per-task payload (default: the tiny ``{"i": i}`` dict)."""
    kwargs = {} if codecs is None else {"codecs": tuple(codecs)}
    server = BrokerServer(make_backend(), **kwargs).start()
    client = NetBroker(server.address)
    payload = payload or (lambda i: {"i": i})
    try:
        client.put_many([new_task("real", payload(i), queue="bench")
                         for i in range(n_tasks)])
        wall = drain(client, n_tasks, n_workers, batch)
        return {"tasks_per_s": n_tasks / wall, "wall_s": wall}
    finally:
        client.close()
        server.stop()


def _arr_payload(floats: int) -> Callable[[int], dict]:
    """Array-heavy payload builder: one float64 ndarray per task — the
    shape the bin1 codec carries as a raw LE buffer and JSON degrades
    to a text list."""
    base = np.arange(floats, dtype=np.float64)
    return lambda i: {"x": base * float(i % 7), "i": i}


def drain_worker_main(cfg_json: str) -> None:
    """Subprocess entrypoint (``--drain-worker``): attach to the given
    endpoints, drain the subscribed queues until they stay empty for
    ``idle_exit`` seconds, report ``{done, t_first, t_last}`` on stdout.

    Separate *processes* matter here: client threads inside the bench
    process convoy on the GIL with the in-process servers' handler
    threads, which hides exactly the contention sharding is built to
    remove.  Real consumers are separate allocations; so are these."""
    import sys
    from repro.core.netbroker import make_broker
    repro_env.configure()  # drainers run on the same recorded defaults
    cfg = json.loads(cfg_json)
    endpoints = cfg["endpoints"]
    if len(endpoints) > 1:
        # a drainer spanning shards must use the BENCH's queue->shard
        # assignment (indices into ITS endpoint list), not the default
        # hash — the parent routed the tasks with an override map
        broker = make_broker(endpoints,
                             queue_shards=cfg.get("queue_shards") or {})
    else:
        broker = make_broker(endpoints[0])
    queues = cfg.get("queues")
    batch = cfg["batch"]
    gate = cfg.get("barrier")
    if gate:
        # ready/go gate: warm the connection (and this process's imports)
        # OUTSIDE the measured window, then start draining in lockstep
        # with the rest of the fleet.  Without this, the [first-lease,
        # last-ack] window swallows n_procs serialized interpreter
        # startups on a small host and measures ramp, not transport.
        broker.qsize()
        open(f"{gate}.ready.{os.getpid()}", "w").close()
        while not os.path.exists(gate):
            time.sleep(0.005)
    done, t_first, t_last = 0, None, None
    idle_since = None
    while True:
        leases = broker.get_many(batch, timeout=0.05, queues=queues)
        now = time.time()
        if not leases:
            if idle_since is None:
                idle_since = now
            elif now - idle_since > cfg["idle_exit"]:
                break
            continue
        idle_since = None
        broker.ack_many([l.tag for l in leases])
        if t_first is None:
            t_first = now
        t_last = now
        done += len(leases)
    broker.close()  # shm channels leak registry entries + segments if not
    json.dump({"done": done, "t_first": t_first, "t_last": t_last},
              sys.stdout)


def _run_drainers(cfgs, timeout: float = 120.0, after_go=None) -> list:
    """Spawn one ``--drain-worker`` subprocess per cfg behind a ready/go
    gate: every worker imports, connects, and reports ready; only then
    does the gate open and the fleet start draining together.  Returns
    the workers' ``{done, t_first, t_last}`` dicts.  ``after_go`` runs
    in the parent the moment the gate opens (the study bench puts its
    tasks there, inside the live-consumer window)."""
    import subprocess
    import sys
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(repo_root, "src")
           + os.pathsep + os.environ.get("PYTHONPATH", "")}
    tmp = tempfile.mkdtemp(prefix="drain-gate-")
    gate = os.path.join(tmp, "go")
    try:
        procs = [subprocess.Popen(
            [sys.executable, "-m", "benchmarks.broker_throughput",
             "--drain-worker", json.dumps({**cfg, "barrier": gate})],
            stdout=subprocess.PIPE, cwd=repo_root, env=env)
            for cfg in cfgs]
        deadline = time.time() + timeout
        while sum(f.startswith("go.ready.")
                  for f in os.listdir(tmp)) < len(procs):
            if time.time() > deadline:
                raise RuntimeError("drain workers never reported ready")
            if any(p.poll() is not None for p in procs):
                raise RuntimeError("a drain worker died before the gate")
            time.sleep(0.01)
        # the parent hosts the broker servers: collect the put_many garbage
        # NOW so a GC pause does not land inside the measured drain window
        gc.collect()
        open(gate, "w").close()
        if after_go is not None:
            after_go()
        return [json.loads(p.communicate(timeout=timeout)[0])
                for p in procs]
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def bench_shard_procs(n_tasks: int, n_shards: int, n_procs: int, batch: int,
                      n_queues: int = 8) -> dict:
    """The federation scenario: ``n_shards`` in-process BrokerServers
    (each fronting its own InMemoryBroker), queues routed across them with
    an explicit round-robin override map (exact load split + exercises the
    override path), drained at saturation by ``n_procs`` drainer
    *processes*, each subscribed to a disjoint queue subset and connected
    only to the shards owning it (the pinned-worker topology).

    ``n_shards=1`` is the single-server control with the identical
    consumer fleet — the apples-to-apples baseline for the federation
    acceptance ratio.  Throughput is total acks over the
    [first-lease, last-ack] window across the fleet (drainers start
    behind the :func:`_run_drainers` gate, so the window measures
    draining, not interpreter startup)."""
    servers = [BrokerServer(InMemoryBroker()).start()
               for _ in range(n_shards)]
    queues = [f"bench{q}" for q in range(n_queues)]
    qmap = {q: i % n_shards for i, q in enumerate(queues)}
    broker = ShardedBroker([s.address for s in servers], queue_shards=qmap)
    try:
        broker.put_many([new_task("real", {"i": i},
                                  queue=queues[i % n_queues])
                         for i in range(n_tasks)])
        cfgs = []
        for p in range(n_procs):
            myq = [q for j, q in enumerate(queues) if j % n_procs == p]
            eps = sorted({f"tcp://127.0.0.1:{servers[qmap[q]].port}"
                          for q in myq})
            cfgs.append({"endpoints": eps, "queues": myq, "batch": batch,
                         "idle_exit": 0.4,
                         "queue_shards": {
                             q: eps.index(
                                 f"tcp://127.0.0.1:{servers[qmap[q]].port}")
                             for q in myq}})
        outs = _run_drainers(cfgs)
    finally:
        broker.close()
        for s in servers:
            s.stop()
    done = sum(o["done"] for o in outs)
    t0 = min(o["t_first"] for o in outs if o["t_first"] is not None)
    t1 = max(o["t_last"] for o in outs if o["t_last"] is not None)
    wall = max(t1 - t0, 1e-9)
    if done < n_tasks:
        raise RuntimeError(f"drainers acked {done}/{n_tasks} tasks")
    return {"tasks_per_s": done / wall, "wall_s": wall}


def bench_shm_procs(n_tasks: int, n_procs: int, batch: int,
                    n_queues: int = 8) -> dict:
    """The same fleet topology as ``bench_shard_procs(n_shards=1, ...)``
    — one server, ``n_procs`` drainer processes on disjoint queue
    subsets — but over the same-host ``shm://`` transport instead of
    loopback TCP: payload frames ride shared-memory rings, waiting
    happens on the unix-socket doorbell, and acks are pipelined
    (fire-and-forget with server-side reply elision).  The direct
    apples-to-apples comparison for ``net_mem_procs4_b8``."""
    from repro.core.netbroker import make_broker
    tmp = tempfile.mkdtemp(prefix="shm-bench-")
    reg = os.path.join(tmp, "registry.json")
    server = BrokerServer(InMemoryBroker(), shm_path=reg).start()
    queues = [f"bench{q}" for q in range(n_queues)]
    broker = make_broker(f"shm://{reg}")
    try:
        broker.put_many([new_task("real", {"i": i},
                                  queue=queues[i % n_queues])
                         for i in range(n_tasks)])
        outs = _run_drainers(
            [{"endpoints": [f"shm://{reg}"],
              "queues": [q for j, q in enumerate(queues)
                         if j % n_procs == p],
              "batch": batch, "idle_exit": 0.4}
             for p in range(n_procs)])
    finally:
        broker.close()
        server.stop()
        shutil.rmtree(tmp, ignore_errors=True)
    done = sum(o["done"] for o in outs)
    t0 = min(o["t_first"] for o in outs if o["t_first"] is not None)
    t1 = max(o["t_last"] for o in outs if o["t_last"] is not None)
    wall = max(t1 - t0, 1e-9)
    if done < n_tasks:
        raise RuntimeError(f"shm drainers acked {done}/{n_tasks} tasks")
    return {"tasks_per_s": done / wall, "wall_s": wall}


def bench_study_codecs(n_tasks: int, n_procs: int = 2, batch: int = 8,
                       floats: int = 1024) -> dict:
    """End-to-end study wall time under each wire codec: producer
    ``put_many`` of array-payload tasks through a live server, drained
    by a worker-process fleet — measured from the first put to the last
    ack, so the producer-side encode cost counts too.  ``json`` runs
    against a server advertising only JSON (the rolling-upgrade
    fallback path); the delta is what the binary codec buys a study."""
    payload = _arr_payload(floats)
    queues = [f"bench{q}" for q in range(n_procs)]
    out = {}
    for label, codecs in (("bin1", ("bin1", "json")), ("json", ("json",))):
        server = BrokerServer(InMemoryBroker(), codecs=codecs).start()
        client = NetBroker(server.address)
        t_put = None
        try:
            # the fleet spawns BEFORE the tasks exist, so the producer's
            # put_many lands inside a live-consumer window like a real
            # study; the gate keeps drainer startup out of that window
            def put_burst():
                nonlocal t_put
                t_put = time.time()
                client.put_many([new_task("real", payload(i),
                                          queue=queues[i % n_procs])
                                 for i in range(n_tasks)])

            outs = _run_drainers(
                [{"endpoints": [server.address], "queues": [queues[p]],
                  "batch": batch, "idle_exit": 2.0}
                 for p in range(n_procs)],
                timeout=180.0, after_go=put_burst)
        finally:
            client.close()
            server.stop()
        done = sum(o["done"] for o in outs)
        if done < n_tasks:
            raise RuntimeError(f"study drainers acked {done}/{n_tasks}")
        t_last = max(o["t_last"] for o in outs if o["t_last"] is not None)
        out[f"{label}_s"] = round(max(t_last - t_put, 1e-9), 4)
    out["delta_s"] = round(out["json_s"] - out["bin1_s"], 4)
    return out


def bench_elastic_rebalance(n_tasks: int, n_queues: int = 24,
                            n_consumers: int = 3) -> dict:
    """Kill-then-join under saturating load: 3 file-backed shards in a
    membership federation, a consumer fleet draining through elastic
    clients; mid-drain one shard dies, heartbeat-TTL eviction re-homes
    its ring share, and a replacement adopts the dead shard's durable
    root and joins.  Measures time-to-rebalance, the fraction of queues
    each membership change moved (bar: <= 2/N), and audits zero task
    loss (every produced id delivered; duplicates are redeliveries the
    once-marker layer absorbs)."""
    from repro.core.hashring import (HashRing, join_membership,
                                     heartbeat_membership, moved_keys,
                                     read_membership, sweep_membership)
    from repro.core.shardbroker import join_federation

    tmp = tempfile.mkdtemp(prefix="elastic-bench-")
    path = os.path.join(tmp, "members.json")
    queues = [f"bench{q}" for q in range(n_queues)]
    servers = {}
    replacement = None
    try:
        for i in range(3):
            s = BrokerServer(FileBroker(os.path.join(tmp, f"shard{i}"),
                                        visibility_timeout=2.0)).start()
            servers[s.address] = s
            join_membership(path, s.address)
        urls = list(servers)
        victim = urls[0]

        # short reconnect_timeout: membership eviction, not TCP-level
        # retry patience, is the elastic failure detector — a client
        # parked 10s on a dead endpoint would measure the reconnect
        # budget, not the rebalance
        sb = ShardedBroker.from_membership(path, refresh_interval=0.05,
                                           reconnect_timeout=1.0)
        produced = [new_task("real", {"i": i}, queue=queues[i % n_queues])
                    for i in range(n_tasks)]
        sb.put_many(produced)
        all_ids = {t.id for t in produced}
        sb.close()

        lock = threading.Lock()
        seen: dict = {}
        done = threading.Event()

        def consume():
            cb = ShardedBroker.from_membership(path, refresh_interval=0.05,
                                               poll_slice=0.02,
                                               reconnect_timeout=1.0)
            try:
                while not done.is_set():
                    try:
                        leases = cb.get_many(8, timeout=0.2)
                    except Exception:
                        continue  # dead shard mid-churn; retry re-routes
                    if not leases:
                        continue
                    try:
                        cb.ack_many([l.tag for l in leases])
                    except Exception:
                        pass  # lost acks redeliver after the vt
                    with lock:
                        for l in leases:
                            seen[l.task.id] = seen.get(l.task.id, 0) + 1
                        if all_ids <= seen.keys():
                            done.set()
            finally:
                cb.close()

        t0 = time.perf_counter()
        threads = [threading.Thread(target=consume)
                   for _ in range(n_consumers)]
        [t.start() for t in threads]

        # let the fleet reach steady state, then kill the victim
        while not done.is_set():
            with lock:
                if len(seen) >= n_tasks // 6:
                    break
            time.sleep(0.02)
        ring_before = HashRing(urls)
        t_kill = time.perf_counter()
        servers.pop(victim).stop()

        # heartbeat the survivors, then TTL-evict the silent victim
        survivors = list(servers)
        for u in survivors:
            heartbeat_membership(path, u)
        m = read_membership(path)
        dead_age = time.time() - float(m.members[victim]["heartbeat_at"])
        sweep_membership(path, ttl=max(dead_age * 0.5, 0.05))
        ring_evicted = HashRing(survivors)
        frac_evict = len(moved_keys(ring_before, ring_evicted,
                                    queues)) / n_queues

        # replacement adopts the dead shard's durable root on a new port
        replacement = BrokerServer(
            FileBroker(os.path.join(tmp, "shard0"),
                       visibility_timeout=2.0)).start()
        res = join_federation(path, replacement.address)
        rebalance_s = time.perf_counter() - t_kill
        ring_after = HashRing(survivors + [replacement.address])
        frac_join = len(moved_keys(ring_evicted, ring_after,
                                   queues)) / n_queues

        if not done.wait(timeout=120.0):
            done.set()
        [t.join(timeout=10.0) for t in threads]
        wall = time.perf_counter() - t0
        with lock:
            lost = len(all_ids - seen.keys())
            dups = sum(c - 1 for c in seen.values())
        if lost:
            raise RuntimeError(
                f"elastic rebalance lost {lost}/{n_tasks} task(s)")
        return {"tasks_per_s": n_tasks / wall, "wall_s": wall,
                "rebalance_s": round(rebalance_s, 4),
                "moved_frac_evict": round(frac_evict, 4),
                "moved_frac_join": round(frac_join, 4),
                "queues_rehomed_on_join": len(res["moved"]),
                "task_loss": lost, "duplicates": dups,
                "n_tasks": n_tasks, "n_queues": n_queues,
                "members": 3}
    finally:
        for s in servers.values():
            s.stop()
        if replacement is not None:
            replacement.stop()
        shutil.rmtree(tmp, ignore_errors=True)


def run(tasks: int = 1000, quick: bool = False,
        out: str = DEFAULT_OUT) -> dict:
    """Run the full scenario matrix, write the artifact, return it
    (with the printable rows under ``"_rows"``)."""
    n = 200 if quick else tasks

    tmp = tempfile.mkdtemp(prefix="broker-bench-")
    rows = []
    scenarios = {}

    def record(name, r, detail=""):
        rows.append((name, r["tasks_per_s"],
                     detail or f"wall={r['wall_s']*1e3:.1f}ms"))
        scenarios[name] = {"tasks_per_s": round(r["tasks_per_s"], 1),
                           "wall_s": round(r["wall_s"], 4)}

    try:
        for workers in (1, 4, 16):
            for batch in (1, 8):
                record(f"mem_w{workers}_b{batch}",
                       bench(InMemoryBroker, n, workers, batch))
        i = 0
        for workers in (1, 4, 16):
            for batch in (1, 8):
                i += 1
                root = os.path.join(tmp, f"file{i}")
                record(f"file_w{workers}_b{batch}",
                       bench(lambda: FileBroker(root), n, workers, batch))
        # NetBroker over real sockets, both server backends, batch sweep:
        # batch 1 pays one round-trip per task; batches amortize it away.
        # Interleaved median-of-3: single-shot net numbers on a shared
        # box drift with background load (the source of the phantom
        # net_file_w1_b8 "regression" — see benchmarks/README.md), and
        # interleaving makes drift hit every scenario equally.
        med = lambda rs: sorted(rs, key=lambda r: r["tasks_per_s"])[len(rs) // 2]
        net_runs: dict = {}
        for rep in range(3):
            for batch in (1, 8, 32):
                net_runs.setdefault(f"net_mem_w1_b{batch}", []).append(
                    bench_net(InMemoryBroker, n, 1, batch))
                root = os.path.join(tmp, f"netfile-r{rep}-b{batch}")
                net_runs.setdefault(f"net_file_w1_b{batch}", []).append(
                    bench_net(lambda: FileBroker(root), n, 1, batch))
        for name, rs in net_runs.items():
            record(name, med(rs))
        # codec A/B at b32 on array-heavy payloads: the same server
        # backend and workload, negotiated bin1 vs forced-JSON (the
        # mixed-fleet fallback).  bin1 carries float64 arrays as raw LE
        # buffers; JSON re-encodes them as text on every hop.
        n_arr = max(320, n // 2)
        arr = _arr_payload(1024)
        bin_runs, json_runs = [], []
        for _ in range(3):
            bin_runs.append(bench_net(InMemoryBroker, n_arr, 1, 32,
                                      payload=arr))
            json_runs.append(bench_net(InMemoryBroker, n_arr, 1, 32,
                                       codecs=("json",), payload=arr))
        record("net_mem_arr_w1_b32_bin1", med(bin_runs))
        record("net_mem_arr_w1_b32_json", med(json_runs))
        codec_ratio = (scenarios["net_mem_arr_w1_b32_bin1"]["tasks_per_s"]
                       / scenarios["net_mem_arr_w1_b32_json"]["tasks_per_s"])
        rows.append(("bin1_vs_json_arr_b32", codec_ratio,
                     f"{codec_ratio:.2f}x (acceptance >= 3x)"))
        # federation: a 4-process consumer fleet saturating ONE server vs
        # the SAME fleet on 2 shards — the topology where claim+ack
        # throughput scales past one broker process.  Floor of 4000 tasks
        # (even in --quick) so the measurement window dwarfs drainer
        # startup and actually saturates the server.  Interleaved
        # median-of-5 per scenario: box-load drift hits both topologies
        # equally, the median is the *sustained* figure (a single broker
        # process's throughput under fleet contention is bimodal —
        # scheduler-lucky runs spike it; "best-of" would reward exactly
        # the luck federation exists to remove), and outlier runs in
        # either direction drop out.
        # --quick keeps the scenario present but lighter (smaller floor,
        # median-of-3): it is a CI smoke of the machinery, not the
        # perf-trajectory measurement
        # Saturation matters: short windows (<~0.5 s) are dominated by
        # scheduler ramp and run-to-run drift on a loaded host; 16k tasks
        # keeps the fleet in steady state for ~1 s+ per rep
        n_procs_tasks = max(4 * n, 4000 if quick else 16000)
        repeats = 3 if quick else 5
        singles, shards, shms = [], [], []
        for _ in range(repeats):
            singles.append(bench_shard_procs(n_procs_tasks, 1, 4, 8))
            shards.append(bench_shard_procs(n_procs_tasks, 2, 4, 8))
            # same fleet, same workload, same queue split — only the
            # transport changes (shm rings + doorbell vs loopback TCP)
            shms.append(bench_shm_procs(n_procs_tasks, 4, 8))
        record("net_mem_procs4_b8", med(singles))
        record("shard2_mem_procs4_b8", med(shards))
        record("shm_w4_b8", med(shms))
        shm_ratio = (scenarios["shm_w4_b8"]["tasks_per_s"]
                     / scenarios["net_mem_procs4_b8"]["tasks_per_s"])
        rows.append(("shm_vs_net_mem_procs4_b8", shm_ratio,
                     f"{shm_ratio:.2f}x (acceptance > 1x)"))
        # elastic federation: kill one shard mid-drain, TTL-evict it,
        # join a replacement adopting the durable root.  Runs in --quick
        # too (the schema fences the scenario + its acceptance keys)
        elastic = bench_elastic_rebalance(600 if quick else 3000)
        record("elastic_rebalance", elastic,
               f"rebalance={elastic['rebalance_s']*1e3:.0f}ms "
               f"moved={elastic['moved_frac_evict']:.2f}/"
               f"{elastic['moved_frac_join']:.2f} "
               f"loss={elastic['task_loss']}")
        elastic_bar = 2.0 / elastic["members"]
        elastic_moved = max(elastic["moved_frac_evict"],
                            elastic["moved_frac_join"])
        scenarios["elastic_rebalance"].update(
            {k: elastic[k] for k in
             ("rebalance_s", "moved_frac_evict", "moved_frac_join",
              "queues_rehomed_on_join", "task_loss", "duplicates",
              "n_tasks", "n_queues", "members")})
        rows.append(("elastic_moved_fraction", elastic_moved,
                     f"{elastic_moved:.2f} (acceptance <= "
                     f"{elastic_bar:.2f} per membership change)"))
        # end-to-end study wall time per codec (meta, not a scenario:
        # it is a wall-clock delta, not a tasks/s figure)
        study = bench_study_codecs(200 if quick else 800)
        # seed-era baseline: single worker, batch 1 — its claim is O(n log n)
        seed = bench(lambda: SeedFileBroker(os.path.join(tmp, "seed")),
                     n, 1, 1)
        record("file_seed_listdir_w1_b1", seed)
        new_w1 = scenarios["file_w1_b1"]["tasks_per_s"]
        speedup = new_w1 / seed["tasks_per_s"]
        rows.append(("file_index_speedup_vs_seed", speedup,
                     f"{speedup:.1f}x at {n} queued tasks"))
        # acceptance: batched NetBroker vs the indexed FileBroker baseline
        net_best = max(scenarios[s]["tasks_per_s"] for s in scenarios
                       if s.startswith("net_") and not s.endswith("_b1"))
        net_ratio = net_best / new_w1
        rows.append(("net_batched_vs_file_w1_b1", net_ratio,
                     f"{net_ratio:.2f}x (acceptance >= 1x)"))
        # acceptance: 2-shard federation vs the single net_mem b=8 server
        # under the identical saturating consumer fleet.  The >=1.3x
        # scaling bar is a multi-core claim: with a single schedulable
        # CPU the fleet is core-bound, not broker-bound, and federation
        # cannot scale past one server by construction (the pre-gate
        # measurement that showed 1.55x on this host was counting worker
        # ramp asymmetry, not broker scaling — benchmarks/README.md).
        # Single-core hosts therefore get a no-regression guard instead.
        shard_bar = 1.3 if len(os.sched_getaffinity(0)) >= 2 else 0.9
        shard_ratio = (scenarios["shard2_mem_procs4_b8"]["tasks_per_s"]
                       / scenarios["net_mem_procs4_b8"]["tasks_per_s"])
        rows.append(("shard2_vs_net_mem_b8", shard_ratio,
                     f"{shard_ratio:.2f}x (acceptance >= {shard_bar}x)"))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    artifact = {
        "meta": {"bench": "broker_throughput", "tasks": n,
                 "quick": bool(quick), "unix_time": time.time(),
                 # negotiated default on the wire (JSON stays the
                 # compatibility floor for mixed fleets)
                 "codec": "bin1",
                 # the applied runtime environment (repro/env.py): perf
                 # numbers are only comparable on recorded defaults
                 "env": repro_env.snapshot(),
                 "study_wall": study},
        "scenarios": scenarios,
        "file_index_speedup_vs_seed": round(speedup, 2),
        "acceptance": {
            "net_batched_vs_file_w1_b1": round(net_ratio, 2),
            "pass_net": bool(net_ratio >= 1.0),
            # contention-regime dependent on small hosts — see
            # benchmarks/README.md (parity when idle CPU caps both
            # topologies; 1.4-2.4x measured under co-resident load);
            # shard_bar records which regime this artifact was held to
            "shard2_vs_net_mem_b8": round(shard_ratio, 2),
            "shard_bar": shard_bar,
            "pass_shard": bool(shard_ratio >= shard_bar),
            "bin1_vs_json_arr_b32": round(codec_ratio, 2),
            "pass_codec": bool(codec_ratio >= 3.0),
            "shm_vs_net_mem_procs4_b8": round(shm_ratio, 2),
            "pass_shm": bool(shm_ratio > 1.0),
            # elastic rebalance: a membership change may move at most
            # 2/N of the queues, and the kill-then-join run must lose
            # nothing (duplicates are redeliveries, absorbed by the
            # once-marker layer — recorded, not gated)
            "elastic_moved_fraction": round(elastic_moved, 4),
            "elastic_moved_bar": round(elastic_bar, 4),
            "elastic_rebalance_s": elastic["rebalance_s"],
            "elastic_task_loss": elastic["task_loss"],
            "pass_elastic": bool(elastic_moved <= elastic_bar
                                 and elastic["task_loss"] == 0),
            "pass": bool(net_ratio >= 1.0 and shard_ratio >= shard_bar
                         and codec_ratio >= 3.0 and shm_ratio > 1.0
                         and elastic_moved <= elastic_bar
                         and elastic["task_loss"] == 0),
        },
    }
    with open(out + ".tmp", "w") as f:
        json.dump(artifact, f, indent=1)
    os.rename(out + ".tmp", out)
    artifact["_rows"] = rows
    return artifact


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=1000,
                    help="queued tasks per configuration")
    ap.add_argument("--quick", action="store_true",
                    help="tiny run (200 tasks) for CI smoke")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="JSON artifact path (schema: benchmarks/README.md; "
                         "default: BENCH_broker.json at the repo root)")
    ap.add_argument("--drain-worker", default=None, metavar="JSON",
                    help=argparse.SUPPRESS)  # bench_shard_procs subprocess
    args = ap.parse_args()
    if args.drain_worker is not None:
        return drain_worker_main(args.drain_worker)
    if args.tasks <= 0:
        ap.error("--tasks must be positive")
    repro_env.configure()  # tuned, recorded defaults (lands in meta.env)

    artifact = run(tasks=args.tasks, quick=args.quick, out=args.out)
    rows = artifact["_rows"]
    n = artifact["meta"]["tasks"]

    print("name,tasks_per_s,detail")
    for name, tps, detail in rows:
        print(f"{name},{tps:.0f},{detail}")
    print()
    print(f"broker throughput @ {n} queued tasks "
          f"(claim+ack, tasks/s; higher is better)")
    for name, tps, detail in rows:
        print(f"  {name:<28} {tps:>12.0f}  {detail}")
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
