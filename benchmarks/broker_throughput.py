"""Broker claim-throughput benchmark: the perf baseline for the task-queue
hot path (paper Sec. 2.3 "server stability" / Figs. 3-6 analogues).

Measures end-to-end drain throughput (claim + ack) in tasks/s for the
local broker backends at 1, 4, and 16 concurrent workers with batch sizes
1 and 8, for the NetBroker (real TCP sockets against a BrokerServer
fronting an InMemoryBroker and a FileBroker) at batch 1/8/32, and for a
reference re-implementation of the *seed* FileBroker claim loop (full
listdir + sort per claim) so every speedup is measured, not asserted.

Writes the ``BENCH_broker.json`` artifact (schema: benchmarks/README.md).
The headline acceptance ratio is NetBroker batched (b>=8) throughput vs
the indexed FileBroker single-worker baseline — i.e. "going over the wire
with batching costs nothing vs the shared-filesystem broker".

Usage: PYTHONPATH=src python -m benchmarks.broker_throughput \
           [--tasks N] [--quick] [--out PATH]
Prints ``name,tasks_per_s,detail`` CSV rows then a human-readable block.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import threading
import time
from typing import Callable, List

from repro.core.netbroker import BrokerServer, NetBroker
from repro.core.queue import FileBroker, InMemoryBroker, Task, new_task


# ---------------------------------------------------------------------------
# seed-era FileBroker claim loop (reference baseline)
# ---------------------------------------------------------------------------

class SeedFileBroker:
    """The pre-index FileBroker hot path: re-list + re-sort the queue
    directory on every single claim.  Kept here (benchmark-only) as the
    baseline the cached-index implementation is compared against."""

    def __init__(self, root: str):
        self.qdir = os.path.join(root, "queue")
        self.cdir = os.path.join(root, "claimed")
        os.makedirs(self.qdir, exist_ok=True)
        os.makedirs(self.cdir, exist_ok=True)
        self._seq = 0

    def put(self, task: Task) -> None:
        self._seq += 1
        name = f"{task.priority}-{self._seq:012d}-{task.id}.json"
        tmp = os.path.join(self.qdir, f".tmp-{name}")
        with open(tmp, "w") as f:
            f.write(task.to_json())
        os.rename(tmp, os.path.join(self.qdir, name))

    def put_many(self, tasks: List[Task]) -> None:
        for t in tasks:
            self.put(t)

    def get_many(self, n: int, timeout: float = 0.0, queues=None) -> list:
        out = []
        names = sorted(x for x in os.listdir(self.qdir)
                       if not x.startswith("."))  # O(n log n) EVERY claim
        for name in names[:n]:
            src = os.path.join(self.qdir, name)
            dst = os.path.join(self.cdir, f"{time.time():.3f}__{name}")
            try:
                os.rename(src, dst)
            except OSError:
                continue
            with open(dst) as f:
                out.append((Task.from_json(f.read()), dst))
        return [type("L", (), {"task": t, "tag": g})() for t, g in out]

    def ack_many(self, tags) -> None:
        for tag in tags:
            try:
                os.unlink(tag)
            except OSError:
                pass


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------

def drain(broker, n_tasks: int, n_workers: int, batch: int) -> float:
    """Drain ``n_tasks`` pre-queued tasks with ``n_workers`` threads;
    returns wall seconds from start to the LAST ack (tail-end empty polls
    don't pollute the measurement)."""
    lock = threading.Lock()
    state = {"done": 0, "t_last": 0.0}
    stop = threading.Event()
    t0 = time.perf_counter()

    def work():
        while not stop.is_set():
            leases = broker.get_many(batch, timeout=0.05)
            if not leases:
                continue  # others may still be in flight; stop flag decides
            broker.ack_many([l.tag for l in leases])
            with lock:
                state["done"] += len(leases)
                state["t_last"] = time.perf_counter()
                if state["done"] >= n_tasks:
                    stop.set()

    threads = [threading.Thread(target=work) for _ in range(n_workers)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    return state["t_last"] - t0


def bench(make_broker: Callable[[], object], n_tasks: int, n_workers: int,
          batch: int) -> dict:
    broker = make_broker()
    broker.put_many([new_task("real", {"i": i}, queue="bench")
                     for i in range(n_tasks)])
    wall = drain(broker, n_tasks, n_workers, batch)
    return {"tasks_per_s": n_tasks / wall, "wall_s": wall}


def bench_net(make_backend: Callable[[], object], n_tasks: int,
              n_workers: int, batch: int) -> dict:
    """Drain through real TCP sockets: BrokerServer + NetBroker client."""
    server = BrokerServer(make_backend()).start()
    client = NetBroker(server.address)
    try:
        client.put_many([new_task("real", {"i": i}, queue="bench")
                         for i in range(n_tasks)])
        wall = drain(client, n_tasks, n_workers, batch)
        return {"tasks_per_s": n_tasks / wall, "wall_s": wall}
    finally:
        client.close()
        server.stop()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=1000,
                    help="queued tasks per configuration")
    ap.add_argument("--quick", action="store_true",
                    help="tiny run (200 tasks) for CI smoke")
    ap.add_argument("--out", default="BENCH_broker.json",
                    help="JSON artifact path (schema: benchmarks/README.md)")
    args = ap.parse_args()
    if args.tasks <= 0:
        ap.error("--tasks must be positive")
    n = 200 if args.quick else args.tasks

    tmp = tempfile.mkdtemp(prefix="broker-bench-")
    rows = []
    scenarios = {}

    def record(name, r, detail=""):
        rows.append((name, r["tasks_per_s"],
                     detail or f"wall={r['wall_s']*1e3:.1f}ms"))
        scenarios[name] = {"tasks_per_s": round(r["tasks_per_s"], 1),
                           "wall_s": round(r["wall_s"], 4)}

    try:
        for workers in (1, 4, 16):
            for batch in (1, 8):
                record(f"mem_w{workers}_b{batch}",
                       bench(InMemoryBroker, n, workers, batch))
        i = 0
        for workers in (1, 4, 16):
            for batch in (1, 8):
                i += 1
                root = os.path.join(tmp, f"file{i}")
                record(f"file_w{workers}_b{batch}",
                       bench(lambda: FileBroker(root), n, workers, batch))
        # NetBroker over real sockets, both server backends, batch sweep:
        # batch 1 pays one round-trip per task; batches amortize it away
        for batch in (1, 8, 32):
            record(f"net_mem_w1_b{batch}",
                   bench_net(InMemoryBroker, n, 1, batch))
        for j, batch in enumerate((1, 8, 32)):
            root = os.path.join(tmp, f"netfile{j}")
            record(f"net_file_w1_b{batch}",
                   bench_net(lambda: FileBroker(root), n, 1, batch))
        # seed-era baseline: single worker, batch 1 — its claim is O(n log n)
        seed = bench(lambda: SeedFileBroker(os.path.join(tmp, "seed")),
                     n, 1, 1)
        record("file_seed_listdir_w1_b1", seed)
        new_w1 = scenarios["file_w1_b1"]["tasks_per_s"]
        speedup = new_w1 / seed["tasks_per_s"]
        rows.append(("file_index_speedup_vs_seed", speedup,
                     f"{speedup:.1f}x at {n} queued tasks"))
        # acceptance: batched NetBroker vs the indexed FileBroker baseline
        net_best = max(scenarios[s]["tasks_per_s"] for s in scenarios
                       if s.startswith("net_") and not s.endswith("_b1"))
        net_ratio = net_best / new_w1
        rows.append(("net_batched_vs_file_w1_b1", net_ratio,
                     f"{net_ratio:.2f}x (acceptance >= 1x)"))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    artifact = {
        "meta": {"bench": "broker_throughput", "tasks": n,
                 "quick": bool(args.quick), "unix_time": time.time()},
        "scenarios": scenarios,
        "file_index_speedup_vs_seed": round(speedup, 2),
        "acceptance": {
            "net_batched_vs_file_w1_b1": round(net_ratio, 2),
            "pass": bool(net_ratio >= 1.0),
        },
    }
    with open(args.out + ".tmp", "w") as f:
        json.dump(artifact, f, indent=1)
    os.rename(args.out + ".tmp", args.out)

    print("name,tasks_per_s,detail")
    for name, tps, detail in rows:
        print(f"{name},{tps:.0f},{detail}")
    print()
    print(f"broker throughput @ {n} queued tasks "
          f"(claim+ack, tasks/s; higher is better)")
    for name, tps, detail in rows:
        print(f"  {name:<28} {tps:>12.0f}  {detail}")
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
