"""Benchmark driver: one harness per paper figure (Sec. 2.3) plus the
device-fusion benchmark from the TPU adaptation.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = the per-unit
latency each figure is about), then a human-readable block.  Paper-claim
comparisons live in EXPERIMENTS.md.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--check-schema]

``--check-schema`` validates the BENCH_*.json artifacts (after --quick
refreshes them, or standalone against the committed ones) and exits
non-zero on a malformed document — CI's fence against perf-trajectory rot.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check-schema", action="store_true",
                    help="validate BENCH_*.json against the documented "
                         "schemas (benchmarks/README.md); exit 1 on errors")
    args = ap.parse_args()

    from repro import env as repro_env
    repro_env.configure()  # every figure runs on tuned, recorded defaults

    from benchmarks import figures as F

    rows = []

    # Fig. 3: enqueue/expansion throughput
    sizes = (100, 1000, 10_000, 100_000) if args.quick else \
        (100, 1000, 10_000, 100_000, 1_000_000)
    enq = F.bench_enqueue(sizes=sizes)
    for r in enq:
        rows.append((f"fig3_enqueue_n{r['n_samples']}",
                     1e6 / max(r["samples_per_s"], 1e-9),
                     f"{r['samples_per_s']:.0f} samples/s; merlin_run="
                     f"{r['merlin_run_s']*1e6:.0f}us"))

    # Fig. 4: startup latency vs workers
    for r in F.bench_startup(n_samples=200 if args.quick else 1000):
        rows.append((f"fig4_startup_w{r['workers']}",
                     r["startup_s"] * 1e6,
                     f"first sim after {r['startup_s']*1e3:.1f} ms"))

    # Fig. 5: per-task overhead
    o = F.bench_overhead(n_samples=500 if args.quick else 2000)
    rows.append(("fig5_overhead_per_task", o["overhead_per_task_s"] * 1e6,
                 f"median_work={o['median_task_s']*1e3:.2f}ms "
                 f"wall={o['wall_s']:.2f}s"))

    # Fig. 6: worker scaling
    for r in F.bench_scaling(n_samples=64 if args.quick else 256):
        rows.append((f"fig6_scaling_w{r['workers']}",
                     r["wall_s"] * 1e6 / 256,
                     f"efficiency={r['efficiency']:.2f} vs ideal"))

    # TPU adaptation: fused-bundle per-sample overhead
    for r in F.bench_fused(bundle_sizes=(1, 16, 256) if args.quick
                           else (1, 16, 256, 1024)):
        rows.append((f"fused_bundle_{r['bundle']}",
                     r["us_per_sample"],
                     f"{r['samples_per_s']:.0f} sims/s"))

    # ensemble hot-path bench: in --quick mode run it tiny and emit the
    # BENCH_ensemble.json perf-trajectory artifact at the repo root
    if args.quick:
        from benchmarks import ensemble_throughput as ET
        et = ET.run(quick=True)
        for scen in ("ragged", "uniform"):
            rows.append((f"ensemble_{scen}",
                         1e6 / et[scen]["fused"]["samples_per_s"],
                         f"{et[scen]['speedup']:.1f}x vs per-task path; "
                         f"{et[scen]['fused']['traces']} compiles "
                         f"(bound {et[scen]['bucket_bound']})"))
        rows.append(("ensemble_surrogate_train",
                     et["surrogate"]["scanned_s"] * 1e6,
                     f"{et['surrogate']['speedup']:.1f}x vs eager loop"))
        xb = et["engine_xbatch"]
        rows.append(("ensemble_engine_xbatch",
                     1e6 / xb["xbatch"]["samples_per_s"],
                     f"{xb['speedup']:.2f}x vs per-worker coalescing "
                     f"(bar >= 2x); launches "
                     f"{xb['per_worker']['launches']} -> "
                     f"{xb['xbatch']['launches']}"))
        md = et.get("mesh_dispatch", {})
        if md and "skipped" not in md:
            rows.append(("ensemble_mesh_dispatch",
                         1e6 / md["jag_sharded"]["samples_per_s"],
                         f"{md['devices']} forced host devices; "
                         f"bit_equal={md['bit_equal']}, jag rel diff "
                         f"{md['jag_max_rel_diff']:.1e}"))
        # broker bench (tiny): refreshes BENCH_broker.json so the perf
        # trajectory covers the federated (sharded) topology too
        from benchmarks import broker_throughput as BT
        bt = BT.run(quick=True)
        shard = bt["scenarios"]["shard2_mem_procs4_b8"]
        rows.append(("broker_shard2_mem_procs4_b8",
                     1e6 / shard["tasks_per_s"],
                     f"{bt['acceptance']['shard2_vs_net_mem_b8']:.2f}x vs "
                     f"one server, same consumer fleet (bar >= "
                     f"{bt['acceptance']['shard_bar']}x)"))
        rows.append(("broker_bin1_vs_json_arr_b32",
                     1e6 / bt["scenarios"][
                         "net_mem_arr_w1_b32_bin1"]["tasks_per_s"],
                     f"{bt['acceptance']['bin1_vs_json_arr_b32']:.2f}x vs "
                     f"JSON on array payloads (bar >= 3x)"))
        rows.append(("broker_shm_w4_b8",
                     1e6 / bt["scenarios"]["shm_w4_b8"]["tasks_per_s"],
                     f"{bt['acceptance']['shm_vs_net_mem_procs4_b8']:.2f}x "
                     f"vs tcp, same-host fleet (bar > 1x)"))
        el = bt["scenarios"]["elastic_rebalance"]
        rows.append(("broker_elastic_rebalance",
                     1e6 / el["tasks_per_s"],
                     f"rebalance {el['rebalance_s']:.2f}s; moved "
                     f"{bt['acceptance']['elastic_moved_fraction']:.2f} of "
                     f"queues (bar <= "
                     f"{bt['acceptance']['elastic_moved_bar']:.2f}); "
                     f"loss={el['task_loss']}"))
        # serving-gateway bench (small fleet): refreshes BENCH_serve.json
        # so the perf trajectory covers the inference tier too
        from benchmarks import serve_latency as SL
        sl = SL.run(quick=True)
        sa = sl["acceptance"]
        cont = sl["scenarios"]["continuous"]
        rows.append(("serve_continuous",
                     1e6 / max(cont["requests_per_s"], 1e-9),
                     f"{sa['continuous_vs_naive_rps']:.2f}x vs "
                     f"flush-per-request (bar >= 2x); p99 "
                     f"{sa['continuous_p99_ms']:.0f}ms vs "
                     f"{sa['naive_p99_ms']:.0f}ms"))
        over = sl["scenarios"]["overload_shed"]
        rows.append(("serve_overload_shed",
                     1e6 / max(over["requests_per_s"], 1e-9),
                     f"shed_rate={sa['shed_rate']:.2f} (bar > 0); "
                     f"accounting_ok={sa['accounting_ok']}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")

    # roofline table if dry-run results exist
    try:
        from benchmarks import roofline
        print()
        roofline.main()
    except Exception as e:  # pragma: no cover
        print(f"(roofline table skipped: {e})", file=sys.stderr)

    if args.check_schema:
        from benchmarks.bench_schema import check_all
        errs = check_all()
        for e in errs:
            print(f"schema error: {e}", file=sys.stderr)
        if errs:
            sys.exit(1)
        print("BENCH_*.json schemas OK")


if __name__ == "__main__":
    main()
