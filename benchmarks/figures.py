"""Benchmark harnesses, one per paper figure (Sec. 2.3).

Fig. 3 — task enqueue throughput vs ensemble size (peak ~3e5 samples/s in
         the paper, plateau above 1e5 samples; `merlin run` itself is O(1)).
Fig. 4 — pre-sample startup latency vs worker count (1000-sample study:
         ~50 s @ 1 worker -> ~3 s @ 4 workers in the paper).
Fig. 5 — per-task overhead distribution (paper: median 32.8 ms,
         right-skewed tail; ours is in-memory + fused so ~1000x lower).
Fig. 6 — makespan vs workers for fixed-duration null tasks (ideal halving).
Extra  — device-fused bundle overhead: the TPU adaptation's per-sample cost.
"""
from __future__ import annotations

import statistics
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.core import hierarchy as H
from repro.core.queue import InMemoryBroker, new_task
from repro.core.runtime import MerlinRuntime
from repro.core.spec import Step, StudySpec
from repro.core.worker import WorkerPool


# ---------------------------------------------------------------------------
# Fig. 3: enqueue + expansion throughput
# ---------------------------------------------------------------------------

def bench_enqueue(sizes=(100, 1000, 10_000, 100_000, 1_000_000),
                  fanout=64, bundle=1) -> List[Dict]:
    rows = []
    for n in sizes:
        cfg = H.HierarchyCfg(max_fanout=fanout, bundle=bundle)
        broker = InMemoryBroker()
        t0 = time.perf_counter()
        broker.put(H.root_task("bench", "0", n, cfg))
        t_root = time.perf_counter() - t0
        # drive the hierarchy to leaves (what workers do collectively);
        # count only generation work — the producer-side cost of Fig. 3
        t0 = time.perf_counter()
        n_real = 0
        while True:
            lease = broker.get(timeout=0)
            if lease is None:
                break
            if lease.task.kind == "gen":
                broker.put_many(H.expand(lease.task))
            else:
                n_real += 1
            broker.ack(lease.tag)
        t_expand = time.perf_counter() - t0
        rows.append({
            "n_samples": n,
            "merlin_run_s": t_root,            # producer: O(1) by design
            "expand_s": t_expand,
            "samples_per_s": n / t_expand if t_expand > 0 else float("inf"),
            "n_real": n_real,
        })
        assert n_real == -(-n // bundle)
    return rows


# ---------------------------------------------------------------------------
# Fig. 4: pre-sample startup time
# ---------------------------------------------------------------------------

def bench_startup(n_samples=1000, workers=(1, 2, 4, 8), bundle=1,
                  fanout=8) -> List[Dict]:
    rows = []
    for w in workers:
        with tempfile.TemporaryDirectory() as ws:
            rt = MerlinRuntime(workspace=ws,
                               hierarchy=H.HierarchyCfg(max_fanout=fanout,
                                                        bundle=bundle))
            rt.register("noop", lambda ctx: None)
            spec = StudySpec(name="b", steps=[Step(name="noop", fn="noop")])
            t0 = time.monotonic()
            pool = WorkerPool(rt, n_workers=w)
            try:
                rt.run(spec, np.zeros((n_samples, 1), np.float32))
                first = None
                deadline = time.monotonic() + 60
                while first is None and time.monotonic() < deadline:
                    starts = [x.first_real_at for x in pool.workers
                              if x.first_real_at]
                    first = min(starts) if starts else None
                    time.sleep(0.001)
                rows.append({"workers": w, "n_samples": n_samples,
                             "startup_s": (first or float("nan")) - t0})
            finally:
                pool.shutdown()
    return rows


# ---------------------------------------------------------------------------
# Fig. 5: per-task overhead distribution
# ---------------------------------------------------------------------------

def bench_overhead(n_samples=2000, bundle=1, sleep_s=0.001) -> Dict:
    with tempfile.TemporaryDirectory() as ws:
        rt = MerlinRuntime(workspace=ws,
                           hierarchy=H.HierarchyCfg(max_fanout=16,
                                                    bundle=bundle))
        durations = []

        def task(ctx):
            t0 = time.perf_counter()
            time.sleep(sleep_s)
            durations.append(time.perf_counter() - t0)

        rt.register("task", task)
        spec = StudySpec(name="o", steps=[Step(name="task", fn="task")])
        wall0 = time.monotonic()
        with WorkerPool(rt, n_workers=4) as pool:
            sid = rt.run(spec, np.zeros((n_samples, 1), np.float32))
            assert rt.wait(sid, timeout=120)
        wall = time.monotonic() - wall0
    # total system overhead per task = (wall * workers - sum(work)) / n
    busy = sum(durations)
    over = (wall * 4 - busy) / n_samples
    return {"n": n_samples, "wall_s": wall, "work_s": busy,
            "overhead_per_task_s": over,
            "median_task_s": statistics.median(durations)}


# ---------------------------------------------------------------------------
# Fig. 6: worker scaling
# ---------------------------------------------------------------------------

def bench_scaling(n_samples=256, task_s=0.01, workers=(1, 2, 4, 8)) -> List[Dict]:
    rows = []
    for w in workers:
        with tempfile.TemporaryDirectory() as ws:
            rt = MerlinRuntime(workspace=ws,
                               hierarchy=H.HierarchyCfg(max_fanout=16,
                                                        bundle=1))
            rt.register("sleep", lambda ctx: time.sleep(task_s))
            spec = StudySpec(name="s", steps=[Step(name="sleep", fn="sleep")])
            t0 = time.monotonic()
            with WorkerPool(rt, n_workers=w) as pool:
                sid = rt.run(spec, np.zeros((n_samples, 1), np.float32))
                assert rt.wait(sid, timeout=120)
            wall = time.monotonic() - t0
            ideal = n_samples * task_s / w
            rows.append({"workers": w, "wall_s": wall, "ideal_s": ideal,
                         "efficiency": ideal / wall})
    return rows


# ---------------------------------------------------------------------------
# device-fused bundles (the TPU adaptation; DESIGN.md)
# ---------------------------------------------------------------------------

def bench_fused(bundle_sizes=(1, 16, 256, 1024), n_total=2048) -> List[Dict]:
    import jax
    from repro.core.ensemble import EnsembleExecutor
    from repro.sim import jag_simulate
    rows = []
    rng = np.random.default_rng(0)
    for bs in bundle_sizes:
        ex = EnsembleExecutor(jag_simulate)
        samples = rng.random((bs, 5)).astype(np.float32)
        ex.run_bundle(0, bs, samples)  # compile
        n_bundles = max(1, n_total // bs)
        t0 = time.perf_counter()
        for i in range(n_bundles):
            ex.run_bundle(i * bs, (i + 1) * bs, samples)
        dt = time.perf_counter() - t0
        rows.append({"bundle": bs, "samples_per_s": n_bundles * bs / dt,
                     "us_per_sample": dt / (n_bundles * bs) * 1e6})
    return rows
