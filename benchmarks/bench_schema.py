"""Schema fences for the BENCH_*.json perf-trajectory artifacts.

``benchmarks/run.py --quick --check-schema`` (CI's smoke path) validates
the artifacts right after writing them, so a refactor that silently stops
emitting a scenario — or emits NaNs/strings where throughput numbers
belong — fails the build instead of rotting the perf trajectory.

The specs are deliberately *minimal* required shapes: extra keys are
always allowed (benches grow), missing/mistyped required ones are errors.
A spec node is either a type tuple (leaf), a dict (required sub-keys), or
a callable predicate returning an error string or None.
"""
from __future__ import annotations

import json
import math
import os
from typing import Any, Dict, List, Optional

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_NUM = (int, float)


def _finite(x: Any) -> bool:
    return isinstance(x, _NUM) and not isinstance(x, bool) \
        and math.isfinite(x)


def _check_node(doc: Any, spec: Any, path: str, errors: List[str]) -> None:
    if callable(spec) and not isinstance(spec, type):
        msg = spec(doc)
        if msg:
            errors.append(f"{path}: {msg}")
        return
    if isinstance(spec, dict):
        if not isinstance(doc, dict):
            errors.append(f"{path}: expected object, got {type(doc).__name__}")
            return
        for key, sub in spec.items():
            if key not in doc:
                errors.append(f"{path}.{key}: missing")
            else:
                _check_node(doc[key], sub, f"{path}.{key}", errors)
        return
    # leaf: type tuple, with numbers required finite
    if spec is _NUM or spec == _NUM:
        if not _finite(doc):
            errors.append(f"{path}: expected finite number, got {doc!r}")
    elif not isinstance(doc, spec):
        errors.append(f"{path}: expected {spec}, got {type(doc).__name__}")


_STREAM = {"tasks": _NUM, "samples": _NUM, "wall_s": _NUM,
           "samples_per_s": _NUM, "traces": _NUM}

_BUNDLE_SCENARIO = {"max_bundle": _NUM, "baseline": _STREAM,
                    "fused": _STREAM, "speedup": _NUM, "bucket_bound": _NUM}

_XBATCH_MODE = {"wall_s": _NUM, "samples_per_s": _NUM, "launches": _NUM}


def _mesh_spec(doc: Any) -> Optional[str]:
    """mesh_dispatch may be {"skipped": reason} (no subprocess support) or
    the full result; both are schema-valid, silence is not."""
    if not isinstance(doc, dict):
        return f"expected object, got {type(doc).__name__}"
    if "skipped" in doc:
        return None if isinstance(doc["skipped"], str) else \
            "skipped must carry a reason string"
    errs: List[str] = []
    _check_node(doc, {
        "devices": _NUM, "bucket_bound": _NUM, "bit_equal": bool,
        "jag_max_rel_diff": _NUM,
        "exact_single": {"wall_s": _NUM, "traces": _NUM},
        "exact_sharded": {"wall_s": _NUM, "traces": _NUM,
                          "mesh_launches": _NUM},
        "jag_single": {"samples_per_s": _NUM},
        "jag_sharded": {"samples_per_s": _NUM, "mesh_launches": _NUM},
    }, "", errs)
    return "; ".join(errs) if errs else None


ENSEMBLE_SPEC: Dict[str, Any] = {
    "meta": {"bench": str, "quick": bool, "jax": str, "backend": str,
             "unix_time": _NUM},
    "ragged": _BUNDLE_SCENARIO,
    "uniform": _BUNDLE_SCENARIO,
    "engine_xbatch": {"n_samples": _NUM, "tasks": _NUM, "bundle": _NUM,
                      "workers": _NUM, "batch": _NUM,
                      "per_worker": _XBATCH_MODE, "xbatch": _XBATCH_MODE,
                      "speedup": _NUM},
    "mesh_dispatch": _mesh_spec,
    "surrogate": {"rows": _NUM, "steps": _NUM, "baseline_s": _NUM,
                  "scanned_s": _NUM, "scanned_cold_s": _NUM,
                  "speedup": _NUM, "prediction_max_abs_diff": _NUM},
    "loads": {"bundles": _NUM, "bundle": _NUM, "cold_load_s": _NUM,
              "warm_load_s": _NUM, "incremental_load_s": _NUM,
              "warm_speedup": _NUM},
    "acceptance": {"engine_xbatch_speedup": _NUM, "pass_xbatch": bool,
                   "pass": bool},
}

# the codec A/B pair and the same-host transport scenarios must be
# present by name: a refactor that silently drops one would leave the
# wire-codec acceptance unmeasured while the artifact still "passes"
_REQUIRED_BROKER_SCENARIOS = ("net_mem_arr_w1_b32_bin1",
                              "net_mem_arr_w1_b32_json",
                              "net_mem_procs4_b8", "shm_w4_b8",
                              "elastic_rebalance")


def _broker_scenarios(d: Any) -> Optional[str]:
    if not (isinstance(d, dict) and d):
        return "expected a non-empty scenarios object"
    bad = [k for k, v in d.items()
           if not (isinstance(v, dict) and _finite(v.get("tasks_per_s"))
                   and _finite(v.get("wall_s")))]
    if bad:
        return f"scenarios need finite tasks_per_s and wall_s: {bad}"
    missing = [k for k in _REQUIRED_BROKER_SCENARIOS if k not in d]
    if missing:
        return f"required scenarios missing: {missing}"
    return None


BROKER_SPEC: Dict[str, Any] = {
    # meta.codec = the wire codec the scenarios were measured under;
    # meta.env = the applied runtime environment (repro/env.py snapshot)
    # — perf numbers are only comparable when both are recorded
    "meta": {"bench": str, "tasks": _NUM, "quick": bool, "unix_time": _NUM,
             "codec": str, "env": dict,
             "study_wall": {"bin1_s": _NUM, "json_s": _NUM,
                            "delta_s": _NUM}},
    "scenarios": _broker_scenarios,
    "file_index_speedup_vs_seed": _NUM,
    "acceptance": {"net_batched_vs_file_w1_b1": _NUM, "pass_net": bool,
                   "shard2_vs_net_mem_b8": _NUM, "pass_shard": bool,
                   "bin1_vs_json_arr_b32": _NUM, "pass_codec": bool,
                   "shm_vs_net_mem_procs4_b8": _NUM, "pass_shm": bool,
                   "elastic_moved_fraction": _NUM,
                   "elastic_moved_bar": _NUM,
                   "elastic_rebalance_s": _NUM,
                   "elastic_task_loss": _NUM,
                   "pass_elastic": bool,
                   "pass": bool},
}


# all three serving scenarios must be present by name: dropping the
# naive baseline (or the overload run) would leave the continuous-
# batching acceptance ratio and the shed gate unmeasured
_REQUIRED_SERVE_SCENARIOS = ("continuous", "naive", "overload_shed")


def _serve_scenarios(d: Any) -> Optional[str]:
    if not (isinstance(d, dict) and d):
        return "expected a non-empty scenarios object"
    errs: List[str] = []
    for name in _REQUIRED_SERVE_SCENARIOS:
        if name not in d:
            errs.append(f"required scenario missing: {name}")
            continue
        sc = d[name]
        if not isinstance(sc, dict):
            errs.append(f"{name}: expected object")
            continue
        for key in ("requests_per_s", "p50_ms", "p99_ms", "issued",
                    "completed", "shed", "expired", "other", "wall_s"):
            if not _finite(sc.get(key)):
                errs.append(f"{name}.{key}: expected finite number, "
                            f"got {sc.get(key)!r}")
        if not isinstance(sc.get("occupancy_hist"), dict):
            errs.append(f"{name}.occupancy_hist: expected object")
    return "; ".join(errs) if errs else None


SERVE_SPEC: Dict[str, Any] = {
    "meta": {"bench": str, "quick": bool, "unix_time": _NUM,
             "clients": _NUM, "requests_per_client": _NUM,
             "rows_per_request": _NUM, "env": dict},
    "scenarios": _serve_scenarios,
    "acceptance": {"continuous_vs_naive_rps": _NUM, "p99_ratio": _NUM,
                   "continuous_p99_ms": _NUM, "naive_p99_ms": _NUM,
                   "shed_rate": _NUM, "accounting_ok": bool,
                   "pass_throughput": bool, "pass_shed": bool,
                   "pass": bool},
}


def check_doc(doc: Any, spec: Dict[str, Any], name: str) -> List[str]:
    errors: List[str] = []
    _check_node(doc, spec, name, errors)
    return errors


def check_file(path: str, spec: Dict[str, Any]) -> List[str]:
    name = os.path.basename(path)
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        return [f"{name}: missing"]
    except json.JSONDecodeError as e:
        return [f"{name}: not valid JSON ({e})"]
    return check_doc(doc, spec, name)


def check_all(root: str = REPO_ROOT) -> List[str]:
    """Validate every artifact at the repo root; returns all errors."""
    return (check_file(os.path.join(root, "BENCH_ensemble.json"),
                       ENSEMBLE_SPEC)
            + check_file(os.path.join(root, "BENCH_broker.json"),
                         BROKER_SPEC)
            + check_file(os.path.join(root, "BENCH_serve.json"),
                         SERVE_SPEC))


if __name__ == "__main__":
    import sys
    errs = check_all()
    for e in errs:
        print(f"schema error: {e}", file=sys.stderr)
    if errs:
        sys.exit(1)
    print("BENCH_*.json schemas OK")
