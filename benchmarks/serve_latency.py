"""Serving-gateway latency/throughput benchmark: continuous batching A/B.

Closed-loop multi-process benchmark for the surrogate inference gateway
(repro/serve/gateway.py).  A fleet of client *processes* (stdlib-only —
``http.client`` + ``random``, no jax/numpy import in client mode) each
runs a closed loop against the gateway: issue one ``/v1/predict``,
wait for the reply, immediately issue the next.  Closed-loop load is
the honest regime for a batching A/B: the offered load adapts to the
server's speed, so the continuous-batching arm cannot win by letting an
open-loop backlog pile up — it wins only by genuinely fusing the
concurrent requests into fewer device launches.

Three scenarios, same snapshot, same fleet:

* ``continuous`` — the gateway's default mode.  Requests that arrive
  while a batch executes are admitted into the next launch at bucket
  boundaries (core/engine.py ContinuousBatcher).
* ``naive``      — flush-per-request baseline (``naive=True``): one
  device launch per request, the pre-batching serving loop.
* ``overload_shed`` — ``max_inflight`` deliberately smaller than the
  fleet, so admission-queue shedding (HTTP 429) engages.

The surrogate is sized so the per-launch cost (ensemble weight
streaming) dominates the per-row cost — the regime where fusing k
concurrent requests into one launch approaches a k-fold win and where
serving real studies actually operates (big ensemble, small queries).

Writes ``BENCH_serve.json`` (schema: benchmarks/bench_schema.py).
Acceptance: continuous >= 2x naive requests/s at comparable p99 (or
>= 2x better p99 at comparable throughput), shed_rate > 0 in the
overload scenario, and strict accounting in every scenario — completed
+ shed + expired == issued, nothing lost, no unexplained statuses.

Usage: PYTHONPATH=src python -m benchmarks.serve_latency \
           [--quick] [--out PATH]
(``--client`` is the internal subprocess entry point.)
"""
from __future__ import annotations

# module top stays stdlib-only: client subprocesses import this file
# without PYTHONPATH=src and must never pay (or need) the jax import
import argparse
import http.client
import json
import os
import random
import socket
import subprocess
import sys
import tempfile
import time

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "..", "BENCH_serve.json")


# ---------------------------------------------------------------------------
# client subprocess: stdlib closed loop
# ---------------------------------------------------------------------------

def _connect(host: str, port: int) -> http.client.HTTPConnection:
    conn = http.client.HTTPConnection(host, port, timeout=60)
    conn.connect()
    # disable Nagle: a request split across small writes would otherwise
    # stall ~40 ms against the server's delayed ACK
    conn.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return conn


def client_main(args) -> int:
    rng = random.Random(args.seed)
    counts = {"issued": 0, "completed": 0, "shed": 0, "expired": 0,
              "other": 0}
    lat_ms = []
    conn = _connect(args.host, args.port)
    body_hdrs = {"Content-Type": "application/json"}
    t_start = time.monotonic()
    for _ in range(args.requests):
        points = [[rng.random() for _ in range(args.dims)]
                  for _ in range(args.rows)]
        payload = {"points": points}
        if args.deadline_ms is not None:
            payload["deadline_ms"] = args.deadline_ms
        blob = json.dumps(payload)
        counts["issued"] += 1
        t0 = time.monotonic()
        try:
            conn.request("POST", "/v1/predict", blob, body_hdrs)
            resp = conn.getresponse()
            resp.read()  # keep-alive: always drain the reply body
            status = resp.status
        except (OSError, http.client.HTTPException):
            # connection hiccup: reconnect once and retry this request
            conn.close()
            conn = _connect(args.host, args.port)
            try:
                conn.request("POST", "/v1/predict", blob, body_hdrs)
                resp = conn.getresponse()
                resp.read()
                status = resp.status
            except (OSError, http.client.HTTPException):
                counts["other"] += 1
                continue
        dt_ms = (time.monotonic() - t0) * 1000.0
        if status == 200:
            counts["completed"] += 1
            lat_ms.append(dt_ms)
        elif status == 429:
            counts["shed"] += 1
        elif status == 504:
            counts["expired"] += 1
        else:
            counts["other"] += 1
    counts["wall_s"] = time.monotonic() - t_start
    counts["lat_ms"] = lat_ms
    print(json.dumps(counts), flush=True)
    return 0


# ---------------------------------------------------------------------------
# orchestrator
# ---------------------------------------------------------------------------

def _percentile(sorted_vals, q: float) -> float:
    if not sorted_vals:
        return float("nan")
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return float(sorted_vals[idx])


def _spawn_fleet(host: str, port: int, clients: int, requests: int,
                 rows: int, dims: int, deadline_ms=None):
    cmd_base = [sys.executable, os.path.abspath(__file__), "--client",
                "--host", host, "--port", str(port),
                "--requests", str(requests), "--rows", str(rows),
                "--dims", str(dims)]
    if deadline_ms is not None:
        cmd_base += ["--deadline-ms", str(deadline_ms)]
    procs = [subprocess.Popen(cmd_base + ["--seed", str(1000 + i)],
                              stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE)
             for i in range(clients)]
    outs = []
    for p in procs:
        stdout, stderr = p.communicate(timeout=600)
        if p.returncode != 0:
            raise RuntimeError(f"client failed rc={p.returncode}: "
                               f"{stderr.decode()[-500:]}")
        outs.append(json.loads(stdout))
    return outs


def _run_scenario(snap, *, naive: bool, clients: int, requests: int,
                  rows: int, max_inflight: int, deadline_ms=None) -> dict:
    from repro.serve.gateway import SurrogateGateway
    gw = SurrogateGateway(snap, max_inflight=max_inflight,
                          max_batch_rows=512, naive=naive).start()
    try:
        outs = _spawn_fleet("127.0.0.1", gw.port, clients, requests,
                            rows, snap.dims, deadline_ms=deadline_ms)
    finally:
        stats = gw.stats()
        gw.stop(drain=True, timeout=10.0)
    agg = {k: sum(o[k] for o in outs)
           for k in ("issued", "completed", "shed", "expired", "other")}
    lat = sorted(ms for o in outs for ms in o["lat_ms"])
    # closed loop: the fleet's effective measurement window is the
    # slowest client's wall (all clients start within process-spawn skew)
    wall = max(o["wall_s"] for o in outs)
    batcher = stats["batcher"]
    return {
        "requests_per_s": agg["completed"] / wall if wall > 0 else 0.0,
        "p50_ms": _percentile(lat, 0.50),
        "p99_ms": _percentile(lat, 0.99),
        "issued": agg["issued"], "completed": agg["completed"],
        "shed": agg["shed"], "expired": agg["expired"],
        "other": agg["other"],
        "wall_s": wall,
        "clients": clients,
        "batches": batcher["batches"],
        "avg_requests_per_batch": batcher["avg_requests_per_batch"],
        "occupancy_hist": {str(k): v
                           for k, v in batcher["occupancy_hist"].items()},
    }


def _build_snapshot(root: str, quick: bool):
    """Synthetic study archive + resident snapshot, sized for the
    weight-streaming regime (launch cost >> per-row cost)."""
    import numpy as np
    from repro.core.active import SurrogateSnapshot
    from repro.core.bundler import Bundler

    dims, n = 5, 128
    rng = np.random.default_rng(7)
    X = rng.random((n, dims), dtype=np.float32)
    # smooth multimodal response surface (what a study's QoI looks like
    # after input normalization)
    y = (np.sin(3.0 * X[:, 0]) + X[:, 1] * X[:, 2]
         + 0.5 * np.cos(2.0 * X[:, 3] + X[:, 4])).astype(np.float32)
    Bundler(root).write_bundle(0, n, {"inputs": X, "yield": y})
    # 48 members x 640 hidden puts one ensemble launch at ~20 ms on a
    # CPU host — an order of magnitude over the per-request HTTP/JSON
    # overhead, so the A/B measures batching, not socket plumbing.
    # (Measured here: bucket-8 launch ~21 ms, bucket-64 ~47 ms, so
    # fusing a 6-client fleet has ~2.7x of physical headroom.)
    return SurrogateSnapshot(root, n_members=48, hidden=640,
                             steps=6 if quick else 25)


def run(quick: bool = False, out: str = DEFAULT_OUT) -> dict:
    # the benchmark fleet speaks unauthenticated HTTP; don't let an
    # ambient operator token turn every request into a 401
    os.environ.pop("REPRO_AUTH_TOKEN", None)
    from repro import env as repro_env
    repro_env.configure()

    import numpy as np

    clients = 4 if quick else 6
    requests = 30 if quick else 150
    rows = 8

    tmp = tempfile.mkdtemp(prefix="bench_serve_")
    try:
        snap = _build_snapshot(tmp, quick)
        # prewarm the jit cache for every bucket the fleet can produce:
        # naive launches land on bucket(rows)=8; fused launches on up to
        # bucket(clients*rows).  Compiles during measurement would be
        # charged to whichever arm hit the size first.
        # every client keeps at most one request outstanding (closed
        # loop), so fused batches top out at clients*rows rows
        size = rows
        while size < clients * rows * 2:
            snap.predict(np.zeros((size, snap.dims), np.float32))
            size *= 2

        scenarios = {}
        # max_inflight == fleet width: each closed-loop client holds at
        # most one outstanding request, so the queue can never exceed the
        # fleet and nothing sheds — and the batcher's admission window
        # ends the moment the whole cohort is back (queue at the bound)
        scenarios["continuous"] = _run_scenario(
            snap, naive=False, clients=clients, requests=requests,
            rows=rows, max_inflight=clients)
        scenarios["naive"] = _run_scenario(
            snap, naive=True, clients=clients, requests=requests,
            rows=rows, max_inflight=clients)
        # overload: admission bound far below the fleet width, so the
        # shed path (429 before admission) engages under contention
        scenarios["overload_shed"] = _run_scenario(
            snap, naive=False, clients=clients, requests=requests,
            rows=rows, max_inflight=1, deadline_ms=2000)
    finally:
        import shutil
        shutil.rmtree(tmp, ignore_errors=True)

    cont, naive, over = (scenarios["continuous"], scenarios["naive"],
                         scenarios["overload_shed"])
    rps_ratio = (cont["requests_per_s"] / naive["requests_per_s"]
                 if naive["requests_per_s"] > 0 else float("inf"))
    p99_ratio = (naive["p99_ms"] / cont["p99_ms"]
                 if cont["p99_ms"] > 0 else float("inf"))
    shed_rate = over["shed"] / over["issued"] if over["issued"] else 0.0
    accounting_ok = all(
        s["completed"] + s["shed"] + s["expired"] == s["issued"]
        and s["other"] == 0 for s in scenarios.values())
    # the 2x bar, either axis: same-or-better tail at double the
    # throughput, or a halved tail without giving the throughput back
    pass_throughput = bool(
        (rps_ratio >= 2.0 and cont["p99_ms"] <= naive["p99_ms"] * 1.25)
        or (p99_ratio >= 2.0 and rps_ratio >= 0.9))
    pass_shed = bool(shed_rate > 0.0)

    artifact = {
        "meta": {"bench": "serve_latency", "quick": bool(quick),
                 "unix_time": time.time(),
                 "clients": clients, "requests_per_client": requests,
                 "rows_per_request": rows,
                 "env": repro_env.snapshot()},
        "scenarios": scenarios,
        "acceptance": {
            "continuous_vs_naive_rps": round(rps_ratio, 2),
            "p99_ratio": round(p99_ratio, 2),
            "continuous_p99_ms": round(cont["p99_ms"], 2),
            "naive_p99_ms": round(naive["p99_ms"], 2),
            "shed_rate": round(shed_rate, 4),
            "accounting_ok": bool(accounting_ok),
            "pass_throughput": pass_throughput,
            "pass_shed": pass_shed,
            "pass": bool(pass_throughput and pass_shed and accounting_ok),
        },
    }
    with open(out + ".tmp", "w") as f:
        json.dump(artifact, f, indent=1)
    os.rename(out + ".tmp", out)
    return artifact


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small fleet / few requests for CI smoke")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--client", action="store_true",
                    help=argparse.SUPPRESS)  # internal subprocess mode
    ap.add_argument("--host", default="127.0.0.1", help=argparse.SUPPRESS)
    ap.add_argument("--port", type=int, default=0, help=argparse.SUPPRESS)
    ap.add_argument("--requests", type=int, default=50,
                    help=argparse.SUPPRESS)
    ap.add_argument("--rows", type=int, default=8, help=argparse.SUPPRESS)
    ap.add_argument("--dims", type=int, default=5, help=argparse.SUPPRESS)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--seed", type=int, default=0, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.client:
        return client_main(args)

    artifact = run(quick=args.quick, out=args.out)
    acc = artifact["acceptance"]
    for name, sc in artifact["scenarios"].items():
        print(f"{name},{sc['requests_per_s']:.1f},"
              f"p50={sc['p50_ms']:.1f}ms p99={sc['p99_ms']:.1f}ms "
              f"batches={sc['batches']} "
              f"avg_req_per_batch={sc['avg_requests_per_batch']:.2f}")
    print(f"\ncontinuous vs naive: {acc['continuous_vs_naive_rps']:.2f}x "
          f"requests/s, p99 {acc['continuous_p99_ms']:.1f}ms vs "
          f"{acc['naive_p99_ms']:.1f}ms "
          f"({'PASS' if acc['pass_throughput'] else 'FAIL'})")
    print(f"overload shed_rate: {acc['shed_rate']:.3f} "
          f"({'PASS' if acc['pass_shed'] else 'FAIL'}), accounting "
          f"{'OK' if acc['accounting_ok'] else 'BROKEN'}")
    print(f"overall: {'PASS' if acc['pass'] else 'FAIL'}")
    return 0 if acc["pass"] else 1


if __name__ == "__main__":
    sys.exit(main())
